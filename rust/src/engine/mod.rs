//! Inference engine — the L3 per-token decode loop where every offloading
//! decision is made.
//!
//! For each token, for each layer:
//!   1. run the attention stage (AOT artifact via PJRT, or native oracle),
//!   2. run the router stage, take top-k experts in rust,
//!   3. snapshot the expert cache (the paper's trace "gray squares"),
//!   4. for each activated expert: cache hit -> use the resident device
//!      buffers; miss -> transfer (dequantize + upload) and insert,
//!      evicting per the configured policy (LRU/LFU/…),
//!   5. optionally guess layer l+1's experts by applying its gate to this
//!      layer's hidden states (speculative prefetch, §3.2) and transfer
//!      them early — synchronously or via the multi-worker transfer
//!      pipeline (§6.1), where demand misses preempt (or join) speculative
//!      jobs and stale queued guesses are cancelled,
//!   6. combine expert outputs with renormalized gate weights + residual.
//!
//! Wallclock is measured; simulated device time is charged to a [`SimClock`]
//! per the hardware profile (DESIGN.md §3): compute per stage, transfer per
//! miss, with prefetched transfers hidden behind compute up to bus
//! serialization.

pub mod batch;
pub mod selfcheck;

use crate::cache::learned::{new_scoreboard, LearnedEviction, Scoreboard};
use crate::cache::{ExpertCache, Policy, PolicyKind};
use crate::metrics::{PipelineStats, PrecisionRecall, RoundBatchStats, SessionTally, Throughput};
use crate::model::sampler::{top_k, Sampler};
use crate::offload::learned::{top_k_stable, LearnedContext, LearnedPredictor};
use crate::offload::pipeline::{BufferPool, TransferPipeline};
use crate::offload::predictor::MarkovPredictor;
use crate::offload::prefetch::{PendingPrefetch, PrefetchConfig, PrefetchSource, TaggedGuess};
use crate::offload::store::HostExpertStore;
use crate::offload::transfer::{FaultAction, FaultPlan, TransferEngine};
use crate::runtime::{Backend, ExpertHandle, KvState};
use crate::sim::costmodel::TokenEvents;
use crate::sim::hardware::{DiskProfile, HwProfile, ModelScale};
use crate::trace::Trace;
use crate::util::simclock::SimClock;
use anyhow::Result;
use std::collections::HashMap;
use std::sync::Arc;
use std::time::Instant;

/// Session id used by the single-sequence [`InferenceEngine::generate`] /
/// [`InferenceEngine::step`] paths; the concurrent serve scheduler assigns
/// its own ids starting from 1.
pub const SOLO_SESSION: u64 = 0;

#[derive(Clone, Copy, Debug)]
pub struct EngineConfig {
    /// Experts kept per layer ("# offloads" = n_experts − capacity).
    pub cache_capacity: usize,
    pub policy: PolicyKind,
    pub prefetch: PrefetchConfig,
    /// Which signal drives prefetch guesses when `prefetch.enabled`:
    /// speculative gating (default), the online Markov model, or the
    /// offline-trained predictor (which needs weights via
    /// [`InferenceEngine::with_predictor`] — without them the learned
    /// source issues nothing).
    pub prefetch_source: PrefetchSource,
    /// Dequant workers in the async transfer pipeline. `0` runs every
    /// transfer synchronously on the engine thread; `>= 1` overlaps
    /// dequantization with compute (demand misses preempt or join
    /// speculative jobs — see `offload::pipeline`).
    pub transfer_workers: usize,
    /// Hardware profile for the simulated clock.
    pub profile: HwProfile,
    /// Disk profile for the tier under host RAM: when the store is tiered
    /// (`HostExpertStore::build_tiered`) and a demanded/prefetched expert is
    /// not RAM-resident, its disk read is charged to the simulated clock
    /// ahead of the PCIe transfer (the second cliff, DESIGN.md §10).
    /// Ignored for all-RAM stores.
    pub disk: DiskProfile,
    pub seed: u64,
    /// Record the full activation/cache trace.
    pub record_trace: bool,
    /// Bounded retry budget for transiently failed demand fetches: each
    /// retry waits an exponential virtual backoff
    /// ([`FETCH_BACKOFF_BASE_S`]) before re-attempting; the budget
    /// exhausted, the fetch error fails the item (per-item isolation).
    pub fetch_retries: usize,
    /// Demand-miss deadline in virtual milliseconds for *degradable*
    /// (interactive) rows in a batched round: when the estimated stall of
    /// a demand fetch exceeds this, the round skips the stalled expert's
    /// gate-weighted contribution (renormalizing the remaining selections,
    /// counted in `degraded_tokens`) instead of stalling. `0` = never
    /// degrade (every miss waits).
    pub demand_deadline_ms: u64,
}

impl EngineConfig {
    pub fn baseline_lru(capacity: usize) -> Self {
        EngineConfig {
            cache_capacity: capacity,
            policy: PolicyKind::Lru,
            prefetch: PrefetchConfig::default(),
            prefetch_source: PrefetchSource::Gate,
            transfer_workers: 0,
            profile: crate::sim::hardware::physical()[0],
            disk: DiskProfile::default(),
            seed: 0,
            record_trace: true,
            fetch_retries: 2,
            demand_deadline_ms: 0,
        }
    }

    /// Resolve the transfer-worker count from CLI flags — the one mapping
    /// shared by every subcommand: `--transfer-workers N`, with the legacy
    /// `--overlap` boolean meaning one worker.
    pub fn transfer_workers_from(args: &crate::util::cliargs::Args) -> Result<usize> {
        Ok(match args.usize_or("transfer-workers", 0)? {
            0 if args.bool("overlap") => 1,
            n => n,
        })
    }

    /// Preset for the concurrent serve path: requested policy + capacity,
    /// optional speculation, no trace recording (traces grow with every
    /// token ever decoded, which a long-lived server must not do).
    pub fn serving(capacity: usize, policy: PolicyKind, prefetch: bool) -> Self {
        EngineConfig {
            cache_capacity: capacity,
            policy,
            prefetch: PrefetchConfig { enabled: prefetch, k: 2 },
            record_trace: false,
            ..EngineConfig::baseline_lru(capacity)
        }
    }
}

impl Default for EngineConfig {
    /// The paper's baseline operating point (LRU, 4-of-8 experts cached).
    fn default() -> Self {
        EngineConfig::baseline_lru(4)
    }
}

/// Base of the exponential *virtual* backoff between demand-fetch retry
/// attempts: attempt `n` (1-based) waits `base * 2^(n-1)` simulated seconds
/// before re-hitting the store. Virtual because injected transient faults
/// model bus/DMA hiccups inside the simulated timeline, not wall-clock I/O.
pub const FETCH_BACKOFF_BASE_S: f64 = 0.002;

/// What `ensure_resident` did about a demanded expert.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum EnsureOutcome {
    /// The expert is on-device; `hit` distinguishes cache hit from a paid
    /// demand transfer (the caller's per-session tally needs the split).
    Resident { hit: bool },
    /// The estimated stall exceeded the caller's demand-miss deadline; the
    /// expert was NOT fetched and nothing was charged to the clock or bus.
    /// Only possible when a deadline was passed in.
    DeadlineBreached,
}

/// Outcome of one `generate` call.
pub struct GenerationOutput {
    pub tokens: Vec<u32>,
    pub generated: Vec<u32>,
    pub trace: Option<Trace>,
    pub events: Vec<TokenEvents>,
    pub throughput: Throughput,
    pub cache_stats: crate::metrics::CacheStats,
    pub spec_pr: PrecisionRecall,
    /// Peak simulated device bytes (static + resident experts + KV).
    pub peak_resident_bytes: usize,
    pub transfer_bytes: u64,
}

/// One session's contribution to a batched round: the token it feeds this
/// round plus the mutable per-session state (`kv`) the step needs. Built by
/// the serve scheduler from [`batch::Session::peek_next`]; results are
/// committed back via [`batch::Session::apply_step`].
pub struct RoundWork<'a> {
    pub session: u64,
    pub tok: u32,
    pub pos: usize,
    /// Counted in the engine's prefill/decode step split (the equivalent of
    /// routing through [`InferenceEngine::step_session_prefill`]).
    pub prefill: bool,
    /// Whether this item may trade quality for latency under a demand-miss
    /// deadline (interactive sessions say yes, batch says no — a batch row
    /// always waits the fetch out, and pins any group it shares).
    pub degradable: bool,
    pub kv: &'a mut KvState,
}

/// Outcome of one [`InferenceEngine::step_round`] call. Items are in input
/// order; a per-item error fails only that item (the scheduler retires the
/// session with a 500), matching the legacy per-session failure isolation.
pub struct RoundResults {
    /// Per item: final logits, or the error that killed the item.
    pub outcomes: Vec<Result<Vec<f32>>>,
    /// Per item cost-model events (misses, activations, hidden transfers,
    /// wasted prefetches) — same semantics as the `ev` out-param of
    /// [`InferenceEngine::step_session`].
    pub events: Vec<TokenEvents>,
    /// This round's batching counters; also merged into the engine-lifetime
    /// totals returned by [`InferenceEngine::round_batch_stats`].
    pub stats: RoundBatchStats,
}

/// Per-item routing product for one layer of a batched round.
struct RoutedItem {
    x_res: Vec<f32>,
    h: Vec<f32>,
    selected: Vec<usize>,
    gate_w: Vec<f32>,
}

/// One engine replica in a multi-worker serve deployment: an
/// [`InferenceEngine`] plus its replica identity. The engine owns the
/// replica-private state (device `ExpertCache`, KV, transfer pipeline,
/// session tallies); the process-wide `HostExpertStore` is shared across
/// replicas through the engine's `Arc` (see [`InferenceEngine::store`]).
/// The serve layer's `ReplicaRouter` assigns sessions to replicas; the
/// `id` is this replica's slot in that router.
pub struct EngineReplica {
    pub id: usize,
    pub engine: InferenceEngine,
}

impl EngineReplica {
    pub fn new(id: usize, engine: InferenceEngine) -> EngineReplica {
        EngineReplica { id, engine }
    }

    /// The single-replica wrapper legacy callers get: replica 0 of 1.
    pub fn solo(engine: InferenceEngine) -> EngineReplica {
        EngineReplica::new(0, engine)
    }
}

pub struct InferenceEngine {
    pub backend: Box<dyn Backend>,
    pub cfg: EngineConfig,
    cache: ExpertCache<ExpertHandle>,
    transfer: TransferEngine,
    pipeline: Option<TransferPipeline>,
    /// Shared f32 buffer pool behind every dequantization (sync and
    /// pipelined); evicted `ExpertHandle::Host` buffers recycle here.
    pool: Arc<BufferPool>,
    clock: SimClock,
    /// In-flight prefetch transfers on the simulated bus, tagged with the
    /// issuing session so cross-session hits are attributable.
    pending_prefetch: Vec<PendingPrefetch>,
    spec_pr: PrecisionRecall,
    /// Per-session accounting (cache traffic + speculation quality); keyed
    /// by the session id passed to [`InferenceEngine::step_session`].
    session_stats: HashMap<u64, SessionTally>,
    /// Total `step_session` invocations over this engine's lifetime — the
    /// serve layer's proof that admission-control decisions (rejects,
    /// sheds) never consume engine work.
    steps: u64,
    /// The prompt-phase share of `steps` (tokens fed through
    /// [`InferenceEngine::step_session_prefill`]); the remainder is decode
    /// work. The chunked-prefill scheduler and `/metrics` report the split.
    prefill_steps: u64,
    /// Demand lookups that were satisfied by an expert a *different*
    /// session prefetched — the shared-cache amortization counter.
    cross_session_prefetch_hits: u64,
    /// Pending speculative guess for the next layer, session-tagged.
    spec_guess: Option<TaggedGuess>,
    /// Offline-trained cross-layer predictor (None = feature off). Feeds
    /// the learned prefetch source and the eviction scoreboard; never
    /// touches the math path, so outputs stay bit-identical with it on.
    predictor: Option<LearnedPredictor>,
    /// Rolling activation history the predictor's features read. Shared
    /// across sessions by design: the cache it protects is shared too.
    pred_ctx: LearnedContext,
    /// Online Markov model, instantiated for the markov prefetch source.
    markov: Option<MarkovPredictor>,
    /// Per-layer imminent-activation probabilities shared with the
    /// learned eviction policies (present iff `policy == Learned`).
    scoreboard: Option<Scoreboard>,
    /// Predictor guess quality: guesses issued for a layer, settled
    /// against the truth at that layer's next visit (aggregate, both
    /// predictor sources; gate speculation keeps its own `spec_pr`).
    pred_pr: PrecisionRecall,
    /// Outstanding predictor guess per target layer, settled at that
    /// layer's next visit.
    pred_outstanding: Vec<Option<Vec<usize>>>,
    /// Prefetch hits credited per [`PrefetchSource`] (indexed by `idx()`).
    prefetch_hits_by_source: [u64; 3],
    /// Scratch for predictor feature/probability vectors (hot path:
    /// one forward per layer per token).
    pred_feat: Vec<f32>,
    pred_probs: Vec<f32>,
    /// Cumulative round-batching counters over every `step_round` call
    /// (DESIGN.md §8); the legacy per-session path never touches them.
    round_stats: RoundBatchStats,
    /// Tokens that shipped with at least one selected expert skipped under
    /// the demand-miss deadline (the degrade path of DESIGN.md §9).
    degraded_tokens: u64,
    trace: Option<Trace>,
    /// Per-layer compute seconds (dense) and per-expert seconds, derived
    /// from the profile and the artifact's true dimensions.
    dense_s_per_layer: f64,
    expert_s: f64,
    store: Arc<HostExpertStore>,
}

impl InferenceEngine {
    pub fn new(
        backend: Box<dyn Backend>,
        store: Arc<HostExpertStore>,
        cfg: EngineConfig,
    ) -> Self {
        Self::with_predictor(backend, store, cfg, None)
    }

    /// [`InferenceEngine::new`] plus an offline-trained predictor. A
    /// predictor whose dimensions do not match the model is dropped (the
    /// CLI validates loudly before getting here; this is the safety net
    /// that keeps a stale weights file from panicking the decode loop).
    pub fn with_predictor(
        backend: Box<dyn Backend>,
        store: Arc<HostExpertStore>,
        cfg: EngineConfig,
        predictor: Option<LearnedPredictor>,
    ) -> Self {
        let mc = *backend.config();
        let predictor = predictor
            .filter(|p| p.n_layers() == mc.n_layers && p.n_experts() == mc.n_experts);
        let scale = ModelScale {
            name: "live",
            n_layers: mc.n_layers,
            hidden: mc.hidden_size,
            ffn: mc.ffn_size,
            n_experts: mc.n_experts,
            top_k: mc.top_k,
            expert_bytes: store.expert_transfer_bytes(),
            expert_bytes_resident: mc.expert_bytes_f32(),
            static_bytes: 0,
        };
        let dense_s_per_layer =
            cfg.profile.compute_time(scale.dense_flops_per_token()) / mc.n_layers as f64;
        let expert_s = cfg.profile.compute_time(scale.expert_flops());
        // the learned policy needs the shared scoreboard Arc, which the
        // Copy `PolicyKind::build` cannot carry — wire it explicitly
        let scoreboard =
            (cfg.policy == PolicyKind::Learned).then(|| new_scoreboard(mc.n_layers, mc.n_experts));
        let cache = match &scoreboard {
            Some(board) => ExpertCache::with_policies(
                cfg.cache_capacity,
                (0..mc.n_layers)
                    .map(|l| {
                        Box::new(LearnedEviction::new(l, Some(board.clone()))) as Box<dyn Policy>
                    })
                    .collect(),
            ),
            None => ExpertCache::new(mc.n_layers, cfg.cache_capacity, cfg.policy, cfg.seed),
        };
        let markov = (cfg.prefetch_source == PrefetchSource::Markov)
            .then(|| MarkovPredictor::new(mc.n_layers, mc.n_experts));
        let pool = BufferPool::new();
        let pipeline = (cfg.transfer_workers > 0).then(|| {
            TransferPipeline::spawn(Arc::clone(&store), Arc::clone(&pool), cfg.transfer_workers)
        });
        let trace = cfg
            .record_trace
            .then(|| Trace::new(mc.n_layers, mc.n_experts, mc.top_k));
        InferenceEngine {
            backend,
            cfg,
            cache,
            transfer: TransferEngine::new(Arc::clone(&store), Arc::clone(&pool)),
            pipeline,
            pool,
            clock: SimClock::new(),
            pending_prefetch: Vec::new(),
            spec_pr: PrecisionRecall::default(),
            session_stats: HashMap::new(),
            steps: 0,
            prefill_steps: 0,
            cross_session_prefetch_hits: 0,
            spec_guess: None,
            predictor,
            pred_ctx: LearnedContext::new(mc.n_layers, mc.n_experts),
            markov,
            scoreboard,
            pred_pr: PrecisionRecall::default(),
            pred_outstanding: vec![None; mc.n_layers],
            prefetch_hits_by_source: [0; 3],
            pred_feat: Vec::new(),
            pred_probs: Vec::new(),
            round_stats: RoundBatchStats::default(),
            degraded_tokens: 0,
            trace,
            dense_s_per_layer,
            expert_s,
            store,
        }
    }

    pub fn config(&self) -> &crate::model::ModelConfig {
        self.backend.config()
    }

    /// The host expert store behind this engine. Under multi-replica
    /// serving every replica's engine holds the SAME `Arc` (one process-
    /// wide RAM budget and disk tier); `Arc::ptr_eq` over these is the
    /// sharing assertion the serve tests use.
    pub fn store(&self) -> &Arc<HostExpertStore> {
        &self.store
    }

    /// Simulated transfer duration of one expert.
    fn transfer_s(&self) -> f64 {
        self.cfg.profile.transfer_time(self.store.expert_transfer_bytes())
    }

    /// Forget any in-flight prefetch record for `(layer, expert)`. Called
    /// when the cached product of a prefetch disappears (eviction) or is
    /// superseded (demand transfer, re-prefetch), so stale records can
    /// neither accumulate in a long-lived server nor credit a later,
    /// unrelated access as a prefetch hit.
    fn drop_pending_prefetch(&mut self, layer: usize, expert: usize) {
        self.pending_prefetch
            .retain(|p| !(p.layer == layer && p.expert == expert));
    }

    /// Ensure `e` is resident in layer `l`'s cache; returns whether it was a
    /// hit and updates the sim clock for any stall. `session` attributes the
    /// lookup (and any cross-session prefetch credit) under concurrency.
    ///
    /// On a miss, when `deadline_s` is set the stall is estimated FIRST,
    /// entirely side-effect-free: pending transient-retry backoff (peeked,
    /// not consumed), any injected delay, the disk read when the expert is
    /// not RAM-resident in a tiered store, plus the residual of a joinable
    /// in-flight prefetch or a full transfer. A breach returns
    /// `DeadlineBreached` before ANY fault is consumed or backoff charged —
    /// the batched round's degrade path (DESIGN.md §9) takes it from there.
    /// Only then does the fault hook on [`TransferEngine`] run: transient
    /// failures are retried up to `cfg.fetch_retries` times with
    /// exponential virtual backoff, permanent failures bail (the caller's
    /// per-item isolation turns that into a failed session, not a downed
    /// engine).
    fn ensure_resident(
        &mut self,
        session: u64,
        l: usize,
        e: usize,
        ev: &mut TokenEvents,
        deadline_s: Option<f64>,
    ) -> Result<EnsureOutcome> {
        // already resident?
        if self.cache.layers[l].access(e).is_some() {
            // if it arrived via an in-flight prefetch, we may still need to
            // wait for the (simulated) bus to finish delivering it
            if let Some(i) = self
                .pending_prefetch
                .iter()
                .position(|p| p.layer == l && p.expert == e)
            {
                let pending = self.pending_prefetch.swap_remove(i);
                self.credit_prefetch(session, l, pending, ev);
            }
            return Ok(EnsureOutcome::Resident { hit: true });
        }
        // miss: a demand fetch that is not RAM-resident in a tiered store
        // pays a disk read ahead of the PCIe hop. Probe residency NOW,
        // before anything promotes the expert (the fetch below does), and
        // remember the charge for the bus reservation.
        let disk_s = if self.store.ram_resident(l, e) {
            0.0
        } else {
            self.cfg.disk.read_time(self.store.expert_transfer_bytes())
        };
        // deadline gate FIRST, side-effect-free: estimate the stall this
        // demand transfer would cost — pending transient-retry backoff
        // (peeked via the non-consuming fault accessors, capped at the
        // retry budget), injected delay, the disk read, and the residual
        // of a joinable in-flight prefetch or a full transfer when there
        // is nothing to join (a join's disk read was charged at prefetch
        // issue, so it is not re-added). Breaching callers get out before
        // any fault is consumed, backoff charged, or bus slot reserved —
        // the shared-cache miss counted by the failed residency probe
        // above is the only trace, and the caller attributes it.
        if let Some(deadline) = deadline_s {
            let now = self.clock.now();
            let retries = self
                .transfer
                .fault
                .pending_transients(l, e)
                .min(self.cfg.fetch_retries as u32);
            let backoff_s: f64 = (1..=retries)
                .map(|i| FETCH_BACKOFF_BASE_S * (1u64 << (i - 1)) as f64)
                .sum();
            let residual = self
                .pending_prefetch
                .iter()
                .find(|p| p.layer == l && p.expert == e)
                .map(|p| (p.done_at - now).max(0.0));
            let stall = backoff_s
                + self.transfer.fault.peek_delay(l, e)
                + residual.unwrap_or_else(|| disk_s + self.transfer_s());
            if stall > deadline {
                return Ok(EnsureOutcome::DeadlineBreached);
            }
        }
        // injected-fault ladder, only past the gate. Transient failures
        // retry with exponential virtual backoff until the budget runs
        // out; the backoff is charged to the sim clock so retried fetches
        // are visibly slower, not silently free.
        let mut attempt: usize = 0;
        let extra_delay_s = loop {
            match self.transfer.fault.check(l, e) {
                FaultAction::Proceed { extra_delay_s } => break extra_delay_s,
                FaultAction::PermanentFail => {
                    anyhow::bail!(
                        "expert (layer {l}, expert {e}): permanent fetch failure injected"
                    );
                }
                FaultAction::TransientFail => {
                    if attempt >= self.cfg.fetch_retries {
                        anyhow::bail!(
                            "expert (layer {l}, expert {e}): fetch still failing after \
                             {attempt} retries"
                        );
                    }
                    attempt += 1;
                    self.transfer.stats.retries += 1;
                    self.clock
                        .advance(FETCH_BACKOFF_BASE_S * (1u64 << (attempt - 1)) as f64);
                }
            }
        };
        // injected stall (e.g. a degraded PCIe link for this expert): paid
        // on the critical path, before the transfer itself
        if extra_delay_s > 0.0 {
            self.clock.advance(extra_delay_s);
        }
        // demand transfer on the critical path. The pending prefetch
        // record for this expert (if any) is consumed here: when the demand
        // JOINS that still-in-flight prefetch, its simulated bus slot was
        // already reserved at issue time and only the residual is charged;
        // otherwise the record is stale (its product was evicted before
        // use) and the demand transfer supersedes it.
        ev.misses += 1;
        let pending = self
            .pending_prefetch
            .iter()
            .position(|p| p.layer == l && p.expert == e)
            .map(|i| self.pending_prefetch.swap_remove(i));
        let mut joined = false;
        let handle = if let Some(p) = &mut self.pipeline {
            // joins an in-flight prefetch of the same expert (no second
            // fetch) or enqueues at demand priority, ahead of every
            // speculative job
            joined = p.submit_demand(l, e);
            match p.wait_for(l, e) {
                Some(r) => {
                    let t0 = Instant::now();
                    let h = self.backend.upload_expert(r.w1, r.w3, r.w2)?;
                    self.transfer.record_upload_ns(t0.elapsed().as_nanos() as u64);
                    if !joined {
                        // fresh demand: its bus reservation happens below;
                        // a joined prefetch recorded its bytes at issue
                        self.transfer.record_scheduled();
                    }
                    h
                }
                // every worker died: degrade to the synchronous path
                None => {
                    if joined {
                        // the joined prefetch recorded these bytes at issue
                        // and fetch() will record them again — cancel the
                        // issue-time record so volume stays exact even here
                        self.transfer.stats.transfers =
                            self.transfer.stats.transfers.saturating_sub(1);
                        self.transfer.stats.bytes = self
                            .transfer
                            .stats
                            .bytes
                            .saturating_sub(self.store.expert_transfer_bytes() as u64);
                    }
                    self.transfer.fetch(self.backend.as_ref(), l, e)?.0
                }
            }
        } else {
            self.transfer.fetch(self.backend.as_ref(), l, e)?.0
        };
        match pending {
            // joined prefetch: the bus already carries this transfer — wait
            // out the residual, no second reservation (no double charge).
            // The prefetch DID satisfy this demand, so it earns the same
            // credit as when the worker finishes first (otherwise the
            // prefetch-hit counters would vary with worker timing).
            Some(p) if joined => self.credit_prefetch(session, l, p, ev),
            // joined an in-flight prefetch whose engine-side record was
            // superseded: its bus slot and bytes were still charged at
            // issue, so a second full reservation here would double-count
            // the transfer. A join NEVER re-reserves the bus (asserted by
            // the byte-parity check in benches/transfer_pipeline.rs).
            None if joined => {}
            // fresh (or superseding) demand transfer: full bus reservation,
            // behind the disk read when the expert was not RAM-resident
            _ => {
                let now = self.clock.now();
                let done = self.transfer.schedule_bus(now + disk_s, self.transfer_s());
                self.clock.advance(done - now);
            }
        }
        if let Some((victim, evicted)) = self.cache.layers[l].insert(e, handle) {
            self.handle_eviction(l, victim, evicted);
        }
        Ok(EnsureOutcome::Resident { hit: false })
    }

    /// Credit one consumed prefetch record — the ONE accounting used both
    /// when the prefetched expert is already resident and when a demand
    /// joins it still in flight, so the counters cannot drift with worker
    /// timing: residual simulated-bus wait (or a fully hidden transfer),
    /// a prefetch hit, and cross-session attribution.
    fn credit_prefetch(
        &mut self,
        session: u64,
        l: usize,
        pending: PendingPrefetch,
        ev: &mut TokenEvents,
    ) {
        let now = self.clock.now();
        if pending.done_at > now {
            self.clock.advance(pending.done_at - now);
        } else {
            ev.hidden_transfers += 1;
        }
        self.cache.layers[l].stats.prefetch_hits += 1;
        self.prefetch_hits_by_source[pending.source.idx()] += 1;
        if pending.session != session {
            // another session's speculation paid for this transfer: the
            // shared cache amortized it across sessions
            self.cross_session_prefetch_hits += 1;
        }
    }

    /// Bookkeeping when `victim` leaves layer `l`'s cache: stale prefetch
    /// records die and host-resident buffers recycle into the pool. (No
    /// pipeline cancellation here: a queued prefetch can only exist for a
    /// NON-resident expert — `prefetch` peeks first and every delivery
    /// untracks before inserting — so an eviction victim structurally
    /// cannot have one; queued-prefetch cancellation happens at guess
    /// supersession instead.)
    fn handle_eviction(&mut self, l: usize, victim: usize, evicted: ExpertHandle) {
        self.drop_pending_prefetch(l, victim);
        if let ExpertHandle::Host { w1, w3, w2 } = evicted {
            self.pool.release(w1);
            self.pool.release(w3);
            self.pool.release(w2);
        }
    }

    /// Issue speculative prefetches for `next_layer` on behalf of `session`.
    /// `source` tags the pending records so hits attribute per guesser.
    fn prefetch(
        &mut self,
        session: u64,
        next_layer: usize,
        guesses: &[usize],
        source: PrefetchSource,
        ev: &mut TokenEvents,
    ) -> Result<()> {
        // a fresh guess round supersedes stale queued guesses for this
        // layer: cancel them before a worker wastes a slot
        let superseded = match &mut self.pipeline {
            Some(p) => p.cancel_superseded(next_layer, guesses),
            None => Vec::new(),
        };
        for e in superseded {
            self.drop_pending_prefetch(next_layer, e);
        }
        for &e in guesses {
            if self.cache.layers[next_layer].peek(e).is_some() {
                continue; // already resident: free
            }
            if self.pipeline.as_ref().is_some_and(|p| p.in_flight(next_layer, e)) {
                continue; // already being fetched: joining is free too
            }
            // transfer early; simulated completion is bus-serialized but NOT
            // awaited — compute continues (overlap). A RAM-missing expert in
            // a tiered store pays its disk read ahead of the PCIe hop;
            // probed before the worker promotes it.
            let now = self.clock.now();
            let disk_s = if self.store.ram_resident(next_layer, e) {
                0.0
            } else {
                self.cfg.disk.read_time(self.store.expert_transfer_bytes())
            };
            let done = self.transfer.schedule_bus(now + disk_s, self.transfer_s());
            // a re-prefetch supersedes any stale record for this expert
            self.drop_pending_prefetch(next_layer, e);
            self.pending_prefetch.push(PendingPrefetch {
                session,
                layer: next_layer,
                expert: e,
                source,
                done_at: done,
            });
            match &mut self.pipeline {
                Some(p) => {
                    p.submit_prefetch(next_layer, e); // uploaded when collected or demanded
                    // bytes are accounted at reservation time (parity with
                    // the sync branch, whose fetch() records them)
                    self.transfer.record_scheduled();
                }
                None => {
                    let (h, _) = self.transfer.fetch(self.backend.as_ref(), next_layer, e)?;
                    if let Some((victim, evicted)) = self.cache.layers[next_layer].insert(e, h) {
                        self.handle_eviction(next_layer, victim, evicted);
                    }
                }
            }
            ev.wasted_prefetches += 1; // provisional; settled below
        }
        Ok(())
    }

    /// Predictor-side work at the end of layer `l`'s routing, shared by
    /// the per-session and batched paths. In order:
    ///
    /// 1. settle the outstanding predictor guess for `l` against the truth
    ///    (correct guesses were not wasted — mirrors the gate settle);
    /// 2. run the offline model for the next boundary `(l+1) % L`, publish
    ///    the probability row to the eviction scoreboard, and (learned
    ///    source) issue the top-k as a prefetch round;
    /// 3. (markov source, last layer) issue whole-token guesses for every
    ///    layer of the next token;
    /// 4. fold `selected` into the rolling context — strictly AFTER
    ///    predicting, matching the trainer's sample order, so inference
    ///    features are distributed like training features.
    ///
    /// Everything here warms caches and moves simulated bytes; nothing
    /// feeds back into hidden states, so decode output stays bit-identical
    /// with the predictor on or off (property-tested).
    fn predictor_layer_hook(
        &mut self,
        session: u64,
        l: usize,
        selected: &[usize],
        gate_w: &[f32],
        ev: &mut TokenEvents,
    ) -> Result<()> {
        if self.predictor.is_none() && self.markov.is_none() {
            return Ok(());
        }
        let n_layers = self.pred_outstanding.len();
        if let Some(g) = self.pred_outstanding[l].take() {
            self.pred_pr.record(&g, selected);
            let correct = g.iter().filter(|e| selected.contains(e)).count();
            // a wrap guess (issued at layer L-1 for the next token's layer
            // 0) settles in the NEXT token's events, where the provisional
            // wasted count lives in the previous entry — the saturation
            // keeps the aggregate conservative rather than wrong
            ev.wasted_prefetches = ev.wasted_prefetches.saturating_sub(correct);
        }
        let prefetching = self.cfg.prefetch.enabled;
        let mut issue: Option<(usize, Vec<usize>)> = None;
        if let Some(pred) = &self.predictor {
            // detach the scratch buffers so the &self.predictor borrow and
            // the &mut buffer borrows never overlap
            let mut feat = std::mem::take(&mut self.pred_feat);
            let mut probs = std::mem::take(&mut self.pred_probs);
            let tl = pred.target_layer(l);
            pred.features_into(&self.pred_ctx, l, selected, gate_w, &mut feat);
            pred.forward_into(l, &feat, &mut probs);
            if let Some(board) = &self.scoreboard {
                board.lock().expect("scoreboard poisoned")[tl].copy_from_slice(&probs);
            }
            if prefetching && self.cfg.prefetch_source == PrefetchSource::Learned {
                issue = Some((tl, top_k_stable(&probs, self.cfg.prefetch.k)));
            }
            self.pred_feat = feat;
            self.pred_probs = probs;
        }
        if let Some((tl, guess)) = issue {
            self.prefetch(session, tl, &guess, PrefetchSource::Learned, ev)?;
            self.pred_outstanding[tl] = Some(guess);
        }
        self.pred_ctx.observe(l, selected);
        let mut markov_issue: Vec<(usize, Vec<usize>)> = Vec::new();
        if let Some(m) = &mut self.markov {
            m.observe(l, selected);
            // whole-token lead: the moment the last layer routes, guess
            // every layer of the next token (the §6.1 trade-off: more lead
            // time than gating, less accuracy)
            if prefetching && l + 1 == n_layers {
                let k = self.cfg.prefetch.k;
                markov_issue = (0..n_layers).map(|tl| (tl, m.predict(tl, k))).collect();
            }
        }
        for (tl, guess) in markov_issue {
            self.prefetch(session, tl, &guess, PrefetchSource::Markov, ev)?;
            self.pred_outstanding[tl] = Some(guess);
        }
        Ok(())
    }

    /// Collect finished pipeline transfers and upload them into the cache.
    fn collect_transfers(&mut self) -> Result<()> {
        let ready = match &mut self.pipeline {
            Some(p) => p.collect_ready(),
            None => return Ok(()),
        };
        for r in ready {
            let t0 = Instant::now();
            let handle = self.backend.upload_expert(r.w1, r.w3, r.w2)?;
            // bytes were recorded when the prefetch reserved the bus
            self.transfer.record_upload_ns(t0.elapsed().as_nanos() as u64);
            if let Some((victim, evicted)) = self.cache.layers[r.layer].insert(r.expert, handle) {
                self.handle_eviction(r.layer, victim, evicted);
            }
        }
        Ok(())
    }

    /// Run one token through the model; returns logits. Single-sequence
    /// convenience over [`InferenceEngine::step_session`] (session
    /// [`SOLO_SESSION`]).
    pub fn step(&mut self, tok: u32, kv: &mut KvState, pos: usize, ev: &mut TokenEvents) -> Result<Vec<f32>> {
        self.step_session(SOLO_SESSION, tok, kv, pos, ev)
    }

    /// [`InferenceEngine::step_session`] for a *prompt* (prefill) token:
    /// the identical computation — same cache, same prefetcher, same
    /// per-session attribution — counted in the engine's prefill/decode
    /// step split. Chunked prefill (`engine::batch::Session::
    /// prefill_chunk`) and teacher-forced prompts route through here.
    pub fn step_session_prefill(
        &mut self,
        session: u64,
        tok: u32,
        kv: &mut KvState,
        pos: usize,
        ev: &mut TokenEvents,
    ) -> Result<Vec<f32>> {
        self.prefill_steps += 1;
        self.step_session(session, tok, kv, pos, ev)
    }

    /// Run one token of `session` through the model; returns logits.
    ///
    /// Concurrent serving interleaves sessions token-by-token on one engine
    /// (DESIGN.md §6). Each call is self-contained with respect to
    /// speculation — a guess issued at layer *l* settles at layer *l+1* of
    /// the same call — but the expert cache, the simulated bus, and any
    /// still-pending prefetch transfers are shared across sessions, which is
    /// exactly the paper's persistent-cache semantics under contention.
    /// Cache traffic and speculation quality are attributed to `session` in
    /// [`InferenceEngine::session_stats`].
    pub fn step_session(
        &mut self,
        session: u64,
        tok: u32,
        kv: &mut KvState,
        pos: usize,
        ev: &mut TokenEvents,
    ) -> Result<Vec<f32>> {
        self.steps += 1;
        if let Some(t) = &mut self.trace {
            t.push_token(tok);
        }
        let token_idx = self.trace.as_ref().map_or(0, |t| t.n_tokens() - 1);

        // baselines for per-session attribution (settled below even when a
        // layer errors mid-token, so the per-session partition of the
        // shared cache's totals stays exact across failures)
        let stats0 = self.cache.total_stats();
        let spec0 = self.spec_pr;
        let wasted0 = ev.wasted_prefetches;

        let result = self.step_layers(session, tok, kv, pos, ev, token_idx);

        // attribute this token's shared-cache traffic to the session
        let stats1 = self.cache.total_stats();
        let spec1 = self.spec_pr;
        let tally = self.session_stats.entry(session).or_default();
        tally.tokens += 1;
        tally.hits += stats1.hits.saturating_sub(stats0.hits);
        tally.misses += stats1.misses.saturating_sub(stats0.misses);
        tally.wasted_prefetches +=
            ev.wasted_prefetches.saturating_sub(wasted0) as u64;
        tally.spec_pr.merge(&PrecisionRecall {
            tp: spec1.tp.saturating_sub(spec0.tp),
            fp: spec1.fp.saturating_sub(spec0.fp),
            fn_: spec1.fn_.saturating_sub(spec0.fn_),
        });
        result
    }

    /// The fallible per-layer body of [`InferenceEngine::step_session`].
    fn step_layers(
        &mut self,
        session: u64,
        tok: u32,
        kv: &mut KvState,
        pos: usize,
        ev: &mut TokenEvents,
        token_idx: usize,
    ) -> Result<Vec<f32>> {
        let mc = *self.backend.config();
        let mut x = self.backend.embed(tok)?;
        for l in 0..mc.n_layers {
            self.collect_transfers()?;
            let x_res = self.backend.attn(l, &x, kv, pos)?;
            self.clock.advance(self.dense_s_per_layer);
            let (h, probs) = self.backend.router(l, &x_res)?;
            let selected = top_k(&probs, mc.top_k);
            ev.activations += selected.len();

            // settle last layer's speculative guess against the truth.
            // The session/layer guard also quietly discards a guess left
            // behind by a step that errored mid-token — the scheduler keeps
            // the engine alive across per-session failures.
            if let Some(g) = self.spec_guess.take() {
                if g.layer == l && g.session == session {
                    self.spec_pr.record(&g.experts, &selected);
                    if let Some(t) = &mut self.trace {
                        t.at_mut(token_idx, l).spec_guess = Some(g.experts.clone());
                    }
                    // correct guesses were not wasted
                    let correct = g.experts.iter().filter(|e| selected.contains(e)).count();
                    ev.wasted_prefetches = ev.wasted_prefetches.saturating_sub(correct);
                }
            }

            // trace snapshot BEFORE the demand lookups (paper's figures)
            if let Some(t) = &mut self.trace {
                let rec = t.at_mut(token_idx, l);
                rec.cached_before = self.cache.layers[l].resident();
                rec.activated = selected.clone();
            }

            // renormalized top-k gate weights
            let wsum: f32 = selected.iter().map(|&e| probs[e]).sum();
            let gate_w: Vec<f32> = selected.iter().map(|&e| probs[e] / wsum).collect();
            if let Some(t) = &mut self.trace {
                t.at_mut(token_idx, l).weights = gate_w.clone();
            }

            // speculative guess for layer l+1 from THIS layer's post-attn
            // hidden states (issued before the expert compute so transfers
            // overlap with it)
            if self.cfg.prefetch.enabled
                && self.cfg.prefetch_source == PrefetchSource::Gate
                && l + 1 < mc.n_layers
            {
                let spec_probs = self.backend.spec_router(l + 1, &x_res)?;
                let guesses = top_k(&spec_probs, self.cfg.prefetch.k);
                self.prefetch(session, l + 1, &guesses, PrefetchSource::Gate, ev)?;
                self.spec_guess = Some(TaggedGuess { session, layer: l + 1, experts: guesses });
            }
            // predictor-side settle/publish/prefetch/observe (no-op
            // without a predictor source or learned policy)
            self.predictor_layer_hook(session, l, &selected, &gate_w, ev)?;

            // expert compute with cache/transfer
            let mut y = vec![0.0f32; mc.hidden_size];
            for (j, &e) in selected.iter().enumerate() {
                self.ensure_resident(session, l, e, ev, None)?;
                let handle = self.cache.layers[l].peek(e).expect("just inserted");
                let out = self.backend.expert(&h, handle)?;
                let w = gate_w[j];
                for (yv, &ov) in y.iter_mut().zip(&out) {
                    *yv += w * ov;
                }
                self.clock.advance(self.expert_s);
            }
            for (xv, (&rv, &yv)) in x.iter_mut().zip(x_res.iter().zip(&y)) {
                *xv = rv + yv;
            }
        }
        self.backend.final_logits(&x)
    }

    /// Attention + routing + speculation for ONE item at ONE layer — the
    /// per-session half of a batched round, running the exact per-item math
    /// of [`InferenceEngine::step_layers`] (bit-identity depends on it).
    /// Returns the routing product plus the item's speculative-settlement
    /// delta (recorded globally here, merged into the session tally by the
    /// caller).
    #[allow(clippy::too_many_arguments)]
    fn route_item(
        &mut self,
        l: usize,
        session: u64,
        x: &[f32],
        kv: &mut KvState,
        pos: usize,
        ev: &mut TokenEvents,
        guess: &mut Option<TaggedGuess>,
        token_idx: usize,
    ) -> Result<(RoutedItem, PrecisionRecall)> {
        let mc = *self.backend.config();
        let x_res = self.backend.attn(l, x, kv, pos)?;
        self.clock.advance(self.dense_s_per_layer);
        let (h, probs) = self.backend.router(l, &x_res)?;
        let selected = top_k(&probs, mc.top_k);
        ev.activations += selected.len();

        // settle this item's previous-layer guess against the truth. The
        // slot is per item (NOT the engine-wide `spec_guess`), so
        // co-rounded sessions cannot clobber each other's guesses; the
        // layer/session guard matches the legacy path's.
        let mut spec_delta = PrecisionRecall::default();
        if let Some(g) = guess.take() {
            if g.layer == l && g.session == session {
                spec_delta.record(&g.experts, &selected);
                self.spec_pr.merge(&spec_delta);
                if let Some(t) = &mut self.trace {
                    t.at_mut(token_idx, l).spec_guess = Some(g.experts.clone());
                }
                let correct = g.experts.iter().filter(|e| selected.contains(e)).count();
                ev.wasted_prefetches = ev.wasted_prefetches.saturating_sub(correct);
            }
        }

        if let Some(t) = &mut self.trace {
            let rec = t.at_mut(token_idx, l);
            rec.cached_before = self.cache.layers[l].resident();
            rec.activated = selected.clone();
        }

        let wsum: f32 = selected.iter().map(|&e| probs[e]).sum();
        let gate_w: Vec<f32> = selected.iter().map(|&e| probs[e] / wsum).collect();
        if let Some(t) = &mut self.trace {
            t.at_mut(token_idx, l).weights = gate_w.clone();
        }

        if self.cfg.prefetch.enabled
            && self.cfg.prefetch_source == PrefetchSource::Gate
            && l + 1 < mc.n_layers
        {
            let spec_probs = self.backend.spec_router(l + 1, &x_res)?;
            let guesses = top_k(&spec_probs, self.cfg.prefetch.k);
            self.prefetch(session, l + 1, &guesses, PrefetchSource::Gate, ev)?;
            *guess = Some(TaggedGuess { session, layer: l + 1, experts: guesses });
        }
        self.predictor_layer_hook(session, l, &selected, &gate_w, ev)?;
        Ok((RoutedItem { x_res, h, selected, gate_w }, spec_delta))
    }

    /// Round-at-a-time stepping (DESIGN.md §8): run every item's attention
    /// and router independently, then group the round's routed rows by
    /// `(layer, expert)` and execute ONE resident-ensure + multi-row FFN
    /// per distinct expert. Sessions co-routed to an expert share a single
    /// fetch + dequant: the first arrival pays it (hit or miss in its
    /// tally, exactly as the legacy path would charge it) and each further
    /// row is a dedup join — one `access()` on the shared cache, i.e. a
    /// plain hit attributed to the joining session, so the per-session
    /// partition of the cache totals stays exact.
    ///
    /// Token/logit streams are bit-identical to stepping each session
    /// through [`InferenceEngine::step_session`] (the proptest suite's
    /// `prop_round_batching_bit_identical`): expert output depends only on
    /// the row's hidden state and the dequantized weights, and
    /// [`Backend::expert_multi`] runs the identical per-row kernel. Cache
    /// eviction order, simulated timings, and prefetch interleavings MAY
    /// diverge between the two paths — none of them feed back into the
    /// math.
    ///
    /// Per-item failure isolation matches the legacy path: an item's error
    /// fails that item (and any row sharing its failed expert group);
    /// engine-wide failures (transfer collection) fail the whole round.
    pub fn step_round(&mut self, work: &mut [RoundWork]) -> RoundResults {
        fn kill_rows(dead: &mut [Option<anyhow::Error>], rows: &[(usize, usize)], err: anyhow::Error) {
            let msg = format!("{err:#}");
            let mut orig = Some(err);
            for &(i, _) in rows {
                dead[i] = Some(
                    orig.take()
                        .unwrap_or_else(|| anyhow::anyhow!("co-routed expert failed: {msg}")),
                );
            }
        }

        let n = work.len();
        let mc = *self.backend.config();
        let mut round = RoundBatchStats { rounds: 1, ..RoundBatchStats::default() };
        let mut events = vec![TokenEvents::default(); n];
        let mut dead: Vec<Option<anyhow::Error>> = (0..n).map(|_| None).collect();
        let mut degraded = vec![false; n];
        let mut xs: Vec<Vec<f32>> = vec![Vec::new(); n];
        let mut guesses: Vec<Option<TaggedGuess>> = (0..n).map(|_| None).collect();
        let mut token_idxs = vec![0usize; n];

        self.backend.begin_round();

        // front matter + embed, per item
        for (i, w) in work.iter().enumerate() {
            self.steps += 1;
            if w.prefill {
                self.prefill_steps += 1;
            }
            if let Some(t) = &mut self.trace {
                t.push_token(w.tok);
            }
            token_idxs[i] = self.trace.as_ref().map_or(0, |t| t.n_tokens() - 1);
            self.session_stats.entry(w.session).or_default().tokens += 1;
            match self.backend.embed(w.tok) {
                Ok(x) => xs[i] = x,
                Err(e) => dead[i] = Some(e),
            }
        }

        for l in 0..mc.n_layers {
            // engine-wide upkeep once per layer; a failure here wedges the
            // engine itself, not one session — fail the whole round
            if let Err(e) = self.collect_transfers() {
                let msg = format!("{e:#}");
                let mut orig = Some(e);
                for d in dead.iter_mut().filter(|d| d.is_none()) {
                    *d = Some(
                        orig.take()
                            .unwrap_or_else(|| anyhow::anyhow!("round engine failure: {msg}")),
                    );
                }
                break;
            }

            // Phase A: attention + routing + speculation per item (KV and
            // attention are inherently per-session; only expert FFNs batch)
            let mut routed: Vec<Option<RoutedItem>> = (0..n).map(|_| None).collect();
            for i in 0..n {
                if dead[i].is_some() {
                    continue;
                }
                let w = &mut work[i];
                let session = w.session;
                match self.route_item(
                    l,
                    session,
                    &xs[i],
                    w.kv,
                    w.pos,
                    &mut events[i],
                    &mut guesses[i],
                    token_idxs[i],
                ) {
                    Ok((item, spec_delta)) => {
                        self.session_stats
                            .entry(session)
                            .or_default()
                            .spec_pr
                            .merge(&spec_delta);
                        routed[i] = Some(item);
                    }
                    Err(e) => dead[i] = Some(e),
                }
            }

            // Phase B: group the round's rows by expert, first-appearance
            // order (deterministic: item order × selection order)
            let mut groups: Vec<(usize, Vec<(usize, usize)>)> = Vec::new();
            for i in 0..n {
                let Some(r) = &routed[i] else { continue };
                for (j, &e) in r.selected.iter().enumerate() {
                    match groups.iter_mut().find(|(ge, _)| *ge == e) {
                        Some((_, rows)) => rows.push((i, j)),
                        None => groups.push((e, vec![(i, j)])),
                    }
                }
            }

            // Phase C: one ensure + one multi-row FFN per distinct expert.
            // Outputs are staged per (item, selection slot) and reduced in
            // selection order below: accumulating in group order would
            // reorder the f32 summation for top_k > 2 and break
            // bit-identity with the per-session path.
            let mut row_outs: Vec<Vec<Option<Vec<f32>>>> =
                (0..n).map(|_| vec![None; mc.top_k]).collect();
            for (e, rows) in groups {
                let live: Vec<(usize, usize)> =
                    rows.into_iter().filter(|&(i, _)| dead[i].is_none()).collect();
                if live.is_empty() {
                    continue;
                }
                round.distinct_experts += 1;
                round.batched_rows += live.len() as u64;
                round.dedup_joins += live.len() as u64 - 1;
                // first arrival pays the fetch (or takes the hit)…
                let (i0, _) = live[0];
                // the demand-miss deadline applies only when EVERY row in
                // the group may degrade: one non-degradable (batch) row and
                // the fetch must happen anyway, so co-routed interactive
                // rows ride it for free rather than skipping the expert
                let deadline_s = (self.cfg.demand_deadline_ms > 0
                    && live.iter().all(|&(i, _)| work[i].degradable))
                    .then(|| self.cfg.demand_deadline_ms as f64 / 1e3);
                match self.ensure_resident(work[i0].session, l, e, &mut events[i0], deadline_s) {
                    Ok(EnsureOutcome::Resident { hit }) => {
                        let t = self.session_stats.entry(work[i0].session).or_default();
                        if hit {
                            t.hits += 1;
                        } else {
                            t.misses += 1;
                        }
                    }
                    Ok(EnsureOutcome::DeadlineBreached) => {
                        // the failed residency probe counted one shared-cache
                        // miss; attribute it to the first arrival so the
                        // per-session partition of the cache totals stays
                        // exact. The group's slots stay `None` and the
                        // reduce below renormalizes around the gap.
                        self.session_stats.entry(work[i0].session).or_default().misses += 1;
                        continue;
                    }
                    Err(err) => {
                        kill_rows(&mut dead, &live, err);
                        continue;
                    }
                }
                // …and every co-routed row joins: `access()` is the single
                // cache-stats increment site, so each join lands as exactly
                // one shared-cache hit, attributed to the joining session
                for &(i, _) in &live[1..] {
                    let _ = self.cache.layers[l].access(e);
                    self.session_stats.entry(work[i].session).or_default().hits += 1;
                }
                let sessions: Vec<u64> =
                    live.iter().map(|&(i, _)| work[i].session).collect();
                let hs: Vec<&[f32]> = live
                    .iter()
                    .map(|&(i, _)| routed[i].as_ref().expect("live row").h.as_slice())
                    .collect();
                let handle = self.cache.layers[l].peek(e).expect("just ensured");
                match self.backend.expert_multi(l, e, &sessions, &hs, handle) {
                    Ok(outs) => {
                        for (&(i, j), out) in live.iter().zip(outs) {
                            row_outs[i][j] = Some(out);
                        }
                        // compute is NOT deduplicated — every row still runs
                        // its FFN — so simulated time charges per row
                        self.clock.advance(self.expert_s * live.len() as f64);
                    }
                    Err(err) => kill_rows(&mut dead, &live, err),
                }
            }

            // gate-weighted sum in selection order, then residual, per item
            for i in 0..n {
                if dead[i].is_some() {
                    continue;
                }
                let r = routed[i].take().expect("live item routed");
                let mut y = vec![0.0f32; r.x_res.len()];
                let complete = row_outs[i]
                    .iter()
                    .zip(&r.gate_w)
                    .all(|(slot, _)| slot.is_some());
                if complete {
                    // every selected expert ran — the exact legacy reduce,
                    // byte-for-byte (bit-identity with the per-session path
                    // rides on this branch being untouched)
                    for (slot, &gw) in row_outs[i].iter_mut().zip(&r.gate_w) {
                        let out = slot.take().expect("checked complete");
                        for (yv, &ov) in y.iter_mut().zip(&out) {
                            *yv += gw * ov;
                        }
                    }
                } else {
                    // degrade (DESIGN.md §9): a deadline-breached group left
                    // gaps. Renormalize the surviving gate weights so the
                    // mixture stays a convex combination, still reducing in
                    // selection order; with every slot gone the token rides
                    // the residual stream alone.
                    let wsum: f32 = row_outs[i]
                        .iter()
                        .zip(&r.gate_w)
                        .filter_map(|(slot, &gw)| slot.as_ref().map(|_| gw))
                        .sum();
                    if wsum > 0.0 {
                        for (slot, &gw) in row_outs[i].iter_mut().zip(&r.gate_w) {
                            if let Some(out) = slot.take() {
                                let w = gw / wsum;
                                for (yv, &ov) in y.iter_mut().zip(&out) {
                                    *yv += w * ov;
                                }
                            }
                        }
                    }
                    degraded[i] = true;
                }
                xs[i] = r.x_res.iter().zip(&y).map(|(&rv, &yv)| rv + yv).collect();
            }
        }

        let mut outcomes: Vec<Result<Vec<f32>>> = Vec::with_capacity(n);
        for i in 0..n {
            // settled even for dead items, matching the legacy path's
            // failure-time attribution
            self.session_stats.entry(work[i].session).or_default().wasted_prefetches +=
                events[i].wasted_prefetches as u64;
            match dead[i].take() {
                Some(e) => outcomes.push(Err(e)),
                None => {
                    // one per TOKEN that lost at least one expert, however
                    // many layers breached
                    if degraded[i] {
                        self.degraded_tokens += 1;
                    }
                    outcomes.push(self.backend.final_logits(&xs[i]));
                }
            }
        }
        self.round_stats.merge(&round);
        RoundResults { outcomes, events, stats: round }
    }

    /// Decode: teacher-force `prompt`, then sample `n_gen` tokens.
    pub fn generate(
        &mut self,
        prompt: &[u32],
        n_gen: usize,
        sampler: &mut Sampler,
    ) -> Result<GenerationOutput> {
        let mc = *self.backend.config();
        // each generate() call is an independent sequence: record the
        // boundary in the trace (predictor evaluation resets there — the
        // accuracy-inflation fix) and reset the online predictor contexts
        // so history never bleeds across unrelated prompts
        if let Some(t) = &mut self.trace {
            t.mark_sequence_boundary();
        }
        self.pred_ctx.reset();
        if let Some(m) = &mut self.markov {
            m.reset_context();
        }
        self.pred_outstanding.iter_mut().for_each(|g| *g = None);
        let mut kv = self.backend.new_kv()?;
        let mut tokens: Vec<u32> = prompt.to_vec();
        let mut generated = Vec::with_capacity(n_gen);
        let mut events = Vec::new();
        let total = prompt.len() + n_gen;
        anyhow::ensure!(total <= mc.max_seq, "sequence {total} exceeds max_seq {}", mc.max_seq);
        anyhow::ensure!(!prompt.is_empty(), "empty prompt");

        let wall0 = Instant::now();
        let sim0 = self.clock.now();
        let mut next_tok: Option<u32> = None;
        let mut peak_bytes = 0usize;
        for pos in 0..total {
            let tok = if pos < prompt.len() { tokens[pos] } else { next_tok.unwrap() };
            if pos >= prompt.len() {
                tokens.push(tok);
                generated.push(tok);
            }
            let mut ev = TokenEvents::default();
            let logits = if pos < prompt.len() {
                self.step_session_prefill(SOLO_SESSION, tok, &mut kv, pos, &mut ev)?
            } else {
                self.step(tok, &mut kv, pos, &mut ev)?
            };
            events.push(ev);
            next_tok = Some(sampler.sample(&logits) as u32);
            let resident = self
                .cache
                .resident_bytes(mc.expert_bytes_f32())
                + KvState::bytes(&mc);
            peak_bytes = peak_bytes.max(resident);
        }

        let wall_s = wall0.elapsed().as_secs_f64();
        let sim_s = self.clock.now() - sim0;
        Ok(GenerationOutput {
            tokens,
            generated,
            trace: self.trace.clone(),
            events,
            throughput: Throughput { tokens: total as u64, wall_s, sim_s },
            cache_stats: self.cache.total_stats(),
            spec_pr: self.spec_pr,
            peak_resident_bytes: peak_bytes,
            transfer_bytes: self.transfer.stats.bytes,
        })
    }

    pub fn cache_stats(&self) -> crate::metrics::CacheStats {
        self.cache.total_stats()
    }
    /// Per-session attribution of the shared cache's traffic and of
    /// speculation quality (keyed by the id given to `step_session`).
    pub fn session_stats(&self) -> &HashMap<u64, SessionTally> {
        &self.session_stats
    }
    /// Copy of one session's tally (zeros if the session never stepped).
    pub fn session_tally(&self, session: u64) -> SessionTally {
        self.session_stats.get(&session).copied().unwrap_or_default()
    }
    /// Remove and return one session's tally (called when a serve session
    /// completes, so the map does not grow with request count).
    pub fn take_session_tally(&mut self, session: u64) -> SessionTally {
        self.session_stats.remove(&session).unwrap_or_default()
    }
    /// Demand lookups satisfied by another session's prefetch — how much
    /// the shared cache amortized speculative transfers across sessions.
    pub fn cross_session_prefetch_hits(&self) -> u64 {
        self.cross_session_prefetch_hits
    }
    /// Total tokens ever stepped through this engine (all sessions,
    /// prompt + generated). Requests shed or rejected by the serve layer's
    /// admission control contribute nothing here.
    pub fn total_steps(&self) -> u64 {
        self.steps
    }
    /// Prompt-phase (prefill) share of [`InferenceEngine::total_steps`].
    pub fn prefill_steps(&self) -> u64 {
        self.prefill_steps
    }
    /// Decode-phase share of [`InferenceEngine::total_steps`].
    pub fn decode_steps(&self) -> u64 {
        self.steps.saturating_sub(self.prefill_steps)
    }
    pub fn spec_precision_recall(&self) -> PrecisionRecall {
        self.spec_pr
    }
    /// Predictor-source guess quality (markov + learned prefetch guesses,
    /// settled at each target layer's next visit). Zeros when no predictor
    /// source ran.
    pub fn predictor_precision_recall(&self) -> PrecisionRecall {
        self.pred_pr
    }
    /// Prefetch hits attributed to each guess source, `(name, hits)` in
    /// [`PrefetchSource::ALL`] order — sums to the cache's
    /// `prefetch_hits` total.
    pub fn prefetch_hits_by_source(&self) -> [(&'static str, u64); 3] {
        let mut out = [("", 0); 3];
        for s in PrefetchSource::ALL {
            out[s.idx()] = (s.name(), self.prefetch_hits_by_source[s.idx()]);
        }
        out
    }
    /// Whether an offline-trained predictor is installed (weights loaded
    /// and dimension-matched).
    pub fn predictor_active(&self) -> bool {
        self.predictor.is_some()
    }
    /// Malformed records dropped by the online markov predictor's
    /// `observe` (always 0 for engine-fed activations; nonzero only if a
    /// trace-driven path feeds it garbage).
    pub fn predictor_skipped_records(&self) -> u64 {
        self.markov.as_ref().map_or(0, |m| m.skipped_records())
    }
    /// Engine-lifetime round-batching counters — zeros when the round path
    /// never ran (solo decoding, or `--round-batching off`).
    pub fn round_batch_stats(&self) -> RoundBatchStats {
        self.round_stats
    }
    /// Transfer-pipeline queue counters plus buffer-pool accounting
    /// (`workers == 0` on the synchronous path — the pool still applies).
    pub fn pipeline_stats(&self) -> PipelineStats {
        match &self.pipeline {
            Some(p) => p.stats(),
            None => PipelineStats {
                pool_allocs: self.pool.allocs(),
                pool_reuses: self.pool.reuses(),
                ..PipelineStats::default()
            },
        }
    }
    pub fn take_trace(&mut self) -> Option<Trace> {
        self.trace.take()
    }
    pub fn sim_now(&self) -> f64 {
        self.clock.now()
    }
    /// Tokens shipped with at least one selected expert skipped under the
    /// demand-miss deadline (`/metrics` → `degraded_tokens`).
    pub fn degraded_tokens(&self) -> u64 {
        self.degraded_tokens
    }
    /// Install a deterministic fault plan on the transfer layer — the
    /// test/bench hook behind every injected delay and fetch failure. An
    /// empty plan (the default) is free on the hot path.
    pub fn inject_faults(&mut self, plan: FaultPlan) {
        self.transfer.set_fault_plan(plan);
    }
    /// Demand fetches re-attempted after a transient failure.
    pub fn fetch_retries_performed(&self) -> u64 {
        self.transfer.stats.retries
    }
    /// Host-tier (RAM-over-disk) counters of the underlying expert store:
    /// all zeros for an all-RAM store (`/metrics` → `host_tier`).
    pub fn host_tier_stats(&self) -> crate::metrics::HostTierStats {
        self.store.tier_stats()
    }
    /// Sessions with at least one in-flight prefetch record — the serve
    /// layer's post-cancel invariant check ("no queued prefetch tagged to a
    /// dead session").
    pub fn pending_prefetch_sessions(&self) -> Vec<u64> {
        let mut ids: Vec<u64> = self.pending_prefetch.iter().map(|p| p.session).collect();
        ids.sort_unstable();
        ids.dedup();
        ids
    }
    /// Forget everything held on behalf of a cancelled session: its queued
    /// (not yet running) pipeline prefetches are cancelled, its in-flight
    /// prefetch records dropped (each bus slot was charged at issue — same
    /// precedent as supersession), its tally removed, and any pending
    /// speculative guess it owned discarded so it can never settle against
    /// a survivor's activations. Callers wanting the tally must
    /// [`InferenceEngine::take_session_tally`] it FIRST. Experts its
    /// prefetches already cached stay — they are shared-cache property and
    /// may serve other sessions (counted as cross-session hits).
    pub fn cancel_session(&mut self, session: u64) {
        let mine: Vec<(usize, usize)> = self
            .pending_prefetch
            .iter()
            .filter(|p| p.session == session)
            .map(|p| (p.layer, p.expert))
            .collect();
        for (l, e) in mine {
            if let Some(p) = &mut self.pipeline {
                p.cancel_queued_prefetch(l, e);
            }
            self.drop_pending_prefetch(l, e);
        }
        self.session_stats.remove(&session);
        if self.spec_guess.as_ref().is_some_and(|g| g.session == session) {
            self.spec_guess = None;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::batch::Session;
    use crate::model::sampler::Sampling;
    use crate::model::weights::generate_weights;
    use crate::model::ModelConfig;
    use crate::quant::Scheme;
    use crate::runtime::native::NativeBackend;

    fn engine_with(tweak: impl FnOnce(&mut EngineConfig)) -> InferenceEngine {
        let weights = Arc::new(generate_weights(ModelConfig::TINY, 42));
        let store = Arc::new(HostExpertStore::build(&weights, Scheme::F32).unwrap());
        let mut cfg = EngineConfig::baseline_lru(4);
        cfg.record_trace = false;
        tweak(&mut cfg);
        InferenceEngine::new(Box::new(NativeBackend::new(weights)), store, cfg)
    }

    /// Fault plan covering EVERY (layer, expert) pair, so the test does not
    /// depend on which experts the router happens to demand.
    fn plan_all(mc: &crate::model::ModelConfig, f: impl Fn(FaultPlan, usize, usize) -> FaultPlan) -> FaultPlan {
        let mut plan = FaultPlan::seeded(7);
        for l in 0..mc.n_layers {
            for e in 0..mc.n_experts {
                plan = f(plan, l, e);
            }
        }
        plan
    }

    #[test]
    fn transient_faults_retry_with_backoff_and_keep_outputs() {
        let prompt = [3u32, 1, 4];
        let clean = {
            let mut eng = engine_with(|_| {});
            let mut s = Sampler::new(Sampling::Greedy, 0);
            let out = eng.generate(&prompt, 5, &mut s).unwrap();
            (out.generated, eng.sim_now())
        };
        let mut eng = engine_with(|c| c.fetch_retries = 2);
        let mc = *eng.config();
        eng.inject_faults(plan_all(&mc, |p, l, e| p.fail_transient(l, e, 1)));
        let mut s = Sampler::new(Sampling::Greedy, 0);
        let out = eng.generate(&prompt, 5, &mut s).unwrap();
        // retried fetches change timing, never tokens
        assert_eq!(out.generated, clean.0, "retries changed outputs");
        assert!(eng.fetch_retries_performed() > 0, "no retry recorded");
        assert!(
            eng.sim_now() > clean.1,
            "backoff must cost virtual time: {} vs clean {}",
            eng.sim_now(),
            clean.1
        );
    }

    #[test]
    fn retry_budget_exhaustion_fails_the_fetch() {
        let mut eng = engine_with(|c| c.fetch_retries = 2);
        let mc = *eng.config();
        eng.inject_faults(plan_all(&mc, |p, l, e| p.fail_transient(l, e, 10)));
        let mut s = Sampler::new(Sampling::Greedy, 0);
        let err = eng.generate(&[3, 1, 4], 2, &mut s).unwrap_err();
        assert!(format!("{err:#}").contains("retries"), "unexpected error: {err:#}");
        // the first demanded expert burned the whole budget, then bailed
        assert_eq!(eng.fetch_retries_performed(), 2);
    }

    #[test]
    fn permanent_fault_fails_without_retrying() {
        let mut eng = engine_with(|_| {});
        let mc = *eng.config();
        eng.inject_faults(plan_all(&mc, |p, l, e| p.fail_permanent(l, e)));
        let mut s = Sampler::new(Sampling::Greedy, 0);
        let err = eng.generate(&[3, 1, 4], 2, &mut s).unwrap_err();
        assert!(format!("{err:#}").contains("permanent"), "unexpected error: {err:#}");
        assert_eq!(eng.fetch_retries_performed(), 0, "permanent faults must not retry");
    }

    /// Drive one session to completion through `step_round`, returning its
    /// tokens.
    fn run_rounds(eng: &mut InferenceEngine, degradable: bool) -> Vec<u32> {
        let mut s = Session::new(1, eng, &[3, 2, 8], 5, Sampler::new(Sampling::Greedy, 1)).unwrap();
        while !s.done {
            let (tok, gen) = s.peek_next();
            let mut work = [RoundWork {
                session: s.id,
                tok,
                pos: s.pos,
                prefill: !gen,
                degradable,
                kv: &mut s.kv,
            }];
            let mut results = eng.step_round(&mut work);
            let logits = results.outcomes.remove(0).unwrap();
            s.apply_step(tok, gen, &logits);
        }
        s.tokens
    }

    #[test]
    fn deadline_breach_degrades_interactive_rounds() {
        // every expert stalls 1000 virtual ms against a 1 ms deadline:
        // every demand miss breaches, yet every round still completes
        let mut eng = engine_with(|c| c.demand_deadline_ms = 1);
        let mc = *eng.config();
        eng.inject_faults(plan_all(&mc, |p, l, e| p.stall_ms(l, e, 1000.0)));
        let tokens = run_rounds(&mut eng, true);
        assert_eq!(tokens.len(), 3 + 5, "degraded session must still finish");
        assert!(eng.degraded_tokens() > 0, "no degrade recorded");
        // the failed residency probes stay attributed: per-session tallies
        // still partition the shared cache's totals exactly
        let total = eng.cache_stats();
        let t = eng.session_tally(1);
        assert_eq!(t.hits, total.hits);
        assert_eq!(t.misses, total.misses);
    }

    #[test]
    fn deadline_gate_runs_before_the_retry_ladder() {
        // transient faults whose estimated backoff alone (2 ms for one
        // pending retry) breaches a 1 ms deadline: the gate must exit
        // side-effect-free — degrading the round WITHOUT consuming a fault,
        // charging a retry, or advancing the clock for backoff
        let mut eng = engine_with(|c| {
            c.demand_deadline_ms = 1;
            c.fetch_retries = 2;
        });
        let mc = *eng.config();
        eng.inject_faults(plan_all(&mc, |p, l, e| p.fail_transient(l, e, 1)));
        let tokens = run_rounds(&mut eng, true);
        assert_eq!(tokens.len(), 3 + 5, "degraded session must still finish");
        assert!(eng.degraded_tokens() > 0, "no degrade recorded");
        assert_eq!(
            eng.fetch_retries_performed(),
            0,
            "deadline breach consumed transient faults before the gate"
        );
    }

    #[test]
    fn batch_rows_pin_the_fetch_and_never_degrade() {
        // same stall, but the row is NOT degradable: the round waits the
        // stall out instead of skipping the expert
        let mut eng = engine_with(|c| c.demand_deadline_ms = 1);
        let mc = *eng.config();
        eng.inject_faults(plan_all(&mc, |p, l, e| p.stall_ms(l, e, 1000.0)));
        let tokens = run_rounds(&mut eng, false);
        assert_eq!(tokens.len(), 3 + 5);
        assert_eq!(eng.degraded_tokens(), 0, "non-degradable row degraded");
        assert!(eng.sim_now() > 1.0, "injected stalls were not paid");
    }

    #[test]
    fn degraded_outputs_match_stall_free_outputs_only_when_nothing_breaches() {
        // control: a deadline with no faults never degrades and stays
        // bit-identical to the no-deadline run
        let base = {
            let mut eng = engine_with(|_| {});
            run_rounds(&mut eng, true)
        };
        let mut eng = engine_with(|c| c.demand_deadline_ms = 60_000);
        let with_deadline = run_rounds(&mut eng, true);
        assert_eq!(eng.degraded_tokens(), 0);
        assert_eq!(with_deadline, base, "idle deadline changed outputs");
    }

    #[test]
    fn cancel_session_drops_prefetch_records_and_tally() {
        let mut eng = engine_with(|c| {
            c.prefetch = PrefetchConfig { enabled: true, k: 2 };
        });
        let mut s = Session::new(1, &eng, &[3, 2, 8], 4, Sampler::new(Sampling::Greedy, 1)).unwrap();
        let mut ev = TokenEvents::default();
        for _ in 0..3 {
            s.step_once(&mut eng, &mut ev).unwrap();
        }
        assert!(eng.session_tally(1).tokens > 0);
        eng.cancel_session(1);
        assert!(
            !eng.pending_prefetch_sessions().contains(&1),
            "cancelled session still owns prefetch records"
        );
        assert_eq!(eng.session_tally(1).tokens, 0, "tally survived cancellation");
    }
}
