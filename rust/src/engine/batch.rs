//! Multi-sequence decoding over one shared expert cache.
//!
//! The paper serves batch size 1; the natural serving extension (and the
//! reason expert caching composes well with batching) is that concurrent
//! sequences decoded in token-lockstep SHARE the per-layer expert cache:
//! a transfer triggered by one sequence is a hit for every other sequence
//! that activates the same expert in the same window — expert traffic
//! amortizes across the batch. This module implements round-robin lockstep
//! decoding of N sessions on one engine and exposes the aggregate stats so
//! the amortization is measurable (see `batch_amortizes_transfers` test
//! and the serve_load example).

use crate::engine::InferenceEngine;
use crate::model::sampler::Sampler;
use crate::runtime::KvState;
use crate::sim::costmodel::TokenEvents;
use anyhow::Result;

/// One in-flight decoding session.
pub struct Session {
    pub id: u64,
    pub tokens: Vec<u32>,
    pub n_prompt: usize,
    pub target_new: usize,
    pub kv: KvState,
    pub pos: usize,
    pub sampler: Sampler,
    pub done: bool,
    /// Next token to feed (sampled from the previous step's logits).
    next_tok: Option<u32>,
}

impl Session {
    pub fn new(
        id: u64,
        engine: &InferenceEngine,
        prompt: &[u32],
        target_new: usize,
        sampler: Sampler,
    ) -> Result<Session> {
        anyhow::ensure!(!prompt.is_empty(), "empty prompt");
        anyhow::ensure!(
            prompt.len() + target_new <= engine.config().max_seq,
            "prompt {} + n_tokens {target_new} exceeds max_seq {}",
            prompt.len(),
            engine.config().max_seq
        );
        Ok(Session {
            id,
            tokens: prompt.to_vec(),
            n_prompt: prompt.len(),
            target_new,
            kv: engine.backend.new_kv()?,
            pos: 0,
            sampler,
            done: false,
            next_tok: None,
        })
    }

    pub fn generated(&self) -> &[u32] {
        &self.tokens[self.n_prompt..]
    }

    /// True when the next `step_once` will feed a *generated* token (the
    /// prompt phase is over) — the serve layer's tokens-generated meter.
    pub fn next_token_is_generated(&self) -> bool {
        self.pos >= self.n_prompt
    }

    /// True while the next step feeds a prompt token (the chunked-prefill
    /// scheduler's phase test).
    pub fn in_prefill(&self) -> bool {
        self.pos < self.n_prompt
    }

    /// Prompt tokens not yet fed through the engine.
    pub fn prefill_remaining(&self) -> usize {
        self.n_prompt.saturating_sub(self.pos)
    }

    /// Advance this session by up to `max_tokens` *prompt* tokens — one
    /// prefill chunk covering the range `[pos, pos + n)` of the prompt.
    /// Stops early at the end of the prompt (it never feeds a generated
    /// token), so callers interleave chunks with decode rounds freely.
    /// Returns the number of tokens advanced.
    ///
    /// Each token runs through [`Session::step_once`] — the exact
    /// discipline of unchunked decoding, including the per-token
    /// `step_session` attribution (shared-cache traffic, speculative
    /// prefetch, sampler state) — so chunked prefill is bit-identical to
    /// feeding the same prompt one token per round. On an engine error
    /// `pos` reflects only the tokens that completed (step_once is
    /// failure-atomic), so the caller can compute the partial advance.
    pub fn prefill_chunk(
        &mut self,
        engine: &mut InferenceEngine,
        max_tokens: usize,
        ev: &mut TokenEvents,
    ) -> Result<usize> {
        let mut n = 0;
        while n < max_tokens && !self.done && self.in_prefill() {
            self.step_once(engine, ev)?;
            n += 1;
        }
        Ok(n)
    }

    /// Advance this session by exactly one token on `engine` (feed the next
    /// prompt or sampled token, step, sample the following token). Sets and
    /// returns `done` when the target length is reached. This is the single
    /// token-feeding discipline shared by offline lockstep decoding and the
    /// online serve scheduler.
    ///
    /// Failure-atomic: on an engine error, no token is appended and `pos`
    /// does not advance, so `generated()` reflects only processed tokens
    /// and a retry feeds the same token again.
    pub fn step_once(
        &mut self,
        engine: &mut InferenceEngine,
        ev: &mut TokenEvents,
    ) -> Result<bool> {
        debug_assert!(!self.done, "step_once on a finished session");
        let (tok, is_generated) = self.peek_next();
        let logits = if is_generated {
            engine.step_session(self.id, tok, &mut self.kv, self.pos, ev)?
        } else {
            // identical step, counted as prefill work in the engine's
            // prefill/decode split
            engine.step_session_prefill(self.id, tok, &mut self.kv, self.pos, ev)?
        };
        Ok(self.apply_step(tok, is_generated, &logits))
    }

    /// The token the next step will feed, and whether it is a *generated*
    /// token (vs a prompt token). Pure read: the round-batching scheduler
    /// peeks every candidate, dispatches one `step_round`, then commits
    /// each result through [`Session::apply_step`] — the same feeding
    /// discipline as [`Session::step_once`], split at the engine call.
    pub fn peek_next(&self) -> (u32, bool) {
        if self.pos < self.n_prompt {
            (self.tokens[self.pos], false)
        } else {
            (self.next_tok.expect("sampled token"), true)
        }
    }

    /// Commit one successfully stepped token (the second half of
    /// [`Session::step_once`]): append it if generated, sample the next
    /// token from `logits`, advance `pos`, and set/return `done`. Call
    /// ONLY with the `(tok, is_generated)` pair returned by `peek_next`
    /// and the logits the engine produced for it — skipping the commit on
    /// an engine error preserves step_once's failure atomicity.
    pub fn apply_step(&mut self, tok: u32, is_generated: bool, logits: &[f32]) -> bool {
        if is_generated {
            self.tokens.push(tok);
        }
        self.next_tok = Some(self.sampler.sample(logits) as u32);
        self.pos += 1;
        if self.pos >= self.n_prompt + self.target_new {
            self.done = true;
        }
        self.done
    }
}

/// Decode all sessions to completion in round-robin token-lockstep.
/// Returns per-token events (for the cost model) aggregated across
/// sessions.
pub fn decode_lockstep(
    engine: &mut InferenceEngine,
    sessions: &mut [Session],
) -> Result<Vec<TokenEvents>> {
    let mut all_events = Vec::new();
    loop {
        let mut progressed = false;
        for s in sessions.iter_mut() {
            if s.done {
                continue;
            }
            let mut ev = TokenEvents::default();
            s.step_once(engine, &mut ev)?;
            all_events.push(ev);
            progressed = true;
        }
        if !progressed {
            break;
        }
    }
    Ok(all_events)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cache::PolicyKind;
    use crate::engine::EngineConfig;
    use crate::model::sampler::{Sampler, Sampling};
    use crate::model::weights::generate_weights;
    use crate::model::ModelConfig;
    use crate::offload::store::HostExpertStore;
    use crate::quant::Scheme;
    use crate::runtime::native::NativeBackend;
    use std::sync::Arc;

    fn engine(capacity: usize) -> InferenceEngine {
        let weights = Arc::new(generate_weights(ModelConfig::TINY, 42));
        let store = Arc::new(HostExpertStore::build(&weights, Scheme::F32).unwrap());
        let mut cfg = EngineConfig::baseline_lru(capacity);
        cfg.policy = PolicyKind::Lfu;
        cfg.record_trace = false;
        InferenceEngine::new(Box::new(NativeBackend::new(weights)), store, cfg)
    }

    #[test]
    fn lockstep_decodes_all_sessions() {
        let mut eng = engine(4);
        let mut sessions = Vec::new();
        for i in 0..3u64 {
            sessions.push(
                Session::new(
                    i,
                    &eng,
                    &[1 + i as u32, 5, 9],
                    4,
                    Sampler::new(Sampling::Greedy, i),
                )
                .unwrap(),
            );
        }
        decode_lockstep(&mut eng, &mut sessions).unwrap();
        for s in &sessions {
            assert!(s.done);
            assert_eq!(s.generated().len(), 4);
        }
    }

    #[test]
    fn lockstep_matches_sequential_outputs() {
        // sharing the cache must not change any session's tokens
        let mut eng1 = engine(8);
        let mut s1 = Session::new(0, &eng1, &[2, 4], 5, Sampler::new(Sampling::Greedy, 0)).unwrap();
        decode_lockstep(&mut eng1, std::slice::from_mut(&mut s1)).unwrap();

        let mut eng2 = engine(8);
        let mut batch = vec![
            Session::new(0, &eng2, &[2, 4], 5, Sampler::new(Sampling::Greedy, 0)).unwrap(),
            Session::new(1, &eng2, &[3, 7], 5, Sampler::new(Sampling::Greedy, 1)).unwrap(),
        ];
        decode_lockstep(&mut eng2, &mut batch).unwrap();
        assert_eq!(batch[0].tokens, s1.tokens, "cache sharing changed outputs");
    }

    #[test]
    fn batch_amortizes_transfers() {
        // N sessions sharing one cache must transfer FEWER bytes per token
        // than N independent single-session engines.
        let n = 4u64;
        let toks_each = 6;

        // shared
        let mut eng = engine(4);
        let mut sessions: Vec<Session> = (0..n)
            .map(|i| {
                Session::new(i, &eng, &[1 + i as u32, 2], toks_each, Sampler::new(Sampling::Greedy, i))
                    .unwrap()
            })
            .collect();
        decode_lockstep(&mut eng, &mut sessions).unwrap();
        let shared_stats = eng.cache_stats();
        let shared_per_token =
            shared_stats.misses as f64 / (n as f64 * (toks_each + 2) as f64);

        // independent
        let mut indep_misses = 0u64;
        for i in 0..n {
            let mut e = engine(4);
            let mut s = Session::new(
                i,
                &e,
                &[1 + i as u32, 2],
                toks_each,
                Sampler::new(Sampling::Greedy, i),
            )
            .unwrap();
            decode_lockstep(&mut e, std::slice::from_mut(&mut s)).unwrap();
            indep_misses += e.cache_stats().misses;
        }
        let indep_per_token = indep_misses as f64 / (n as f64 * (toks_each + 2) as f64);
        assert!(
            shared_per_token <= indep_per_token + 1e-9,
            "shared {shared_per_token} vs independent {indep_per_token}"
        );
    }

    #[test]
    fn lockstep_attributes_traffic_per_session() {
        let mut eng = engine(4);
        let mut sessions: Vec<Session> = (1..=3u64)
            .map(|i| {
                Session::new(i, &eng, &[i as u32, 2, 8], 5, Sampler::new(Sampling::Greedy, i))
                    .unwrap()
            })
            .collect();
        decode_lockstep(&mut eng, &mut sessions).unwrap();
        let total = eng.cache_stats();
        let mut hits = 0;
        let mut misses = 0;
        let mut tokens = 0;
        for i in 1..=3u64 {
            let t = eng.session_tally(i);
            assert_eq!(t.tokens, 8, "session {i} stepped {} tokens", t.tokens);
            hits += t.hits;
            misses += t.misses;
            tokens += t.tokens;
        }
        // per-session tallies partition the shared cache's totals exactly
        assert_eq!(hits, total.hits);
        assert_eq!(misses, total.misses);
        assert_eq!(tokens, 24);
    }

    #[test]
    fn prefill_chunk_matches_per_token_stepping() {
        // chunked prefill must be the same computation as feeding the
        // prompt one step_once at a time: same tokens, same engine totals
        let prompt = [3u32, 1, 4, 1, 5, 9, 2, 6];
        let stepped = {
            let mut eng = engine(4);
            let mut s =
                Session::new(0, &eng, &prompt, 4, Sampler::new(Sampling::Greedy, 0)).unwrap();
            let mut ev = TokenEvents::default();
            while !s.done {
                s.step_once(&mut eng, &mut ev).unwrap();
            }
            (s.tokens, eng.total_steps(), eng.prefill_steps())
        };
        let chunked = {
            let mut eng = engine(4);
            let mut s =
                Session::new(0, &eng, &prompt, 4, Sampler::new(Sampling::Greedy, 0)).unwrap();
            let mut ev = TokenEvents::default();
            // ragged chunks covering the whole prompt, interleaved with
            // nothing (chunking is a scheduling concern, not a math one)
            assert_eq!(s.prefill_chunk(&mut eng, 3, &mut ev).unwrap(), 3);
            assert_eq!(s.prefill_chunk(&mut eng, 2, &mut ev).unwrap(), 2);
            assert!(s.in_prefill());
            assert_eq!(s.prefill_remaining(), 3);
            // over-asking stops at the end of the prompt
            assert_eq!(s.prefill_chunk(&mut eng, 100, &mut ev).unwrap(), 3);
            assert!(!s.in_prefill(), "prompt fully fed");
            // a chunk never feeds generated tokens
            assert_eq!(s.prefill_chunk(&mut eng, 100, &mut ev).unwrap(), 0);
            while !s.done {
                s.step_once(&mut eng, &mut ev).unwrap();
            }
            (s.tokens, eng.total_steps(), eng.prefill_steps())
        };
        assert_eq!(stepped, chunked, "chunked prefill diverged from per-token stepping");
        assert_eq!(chunked.2, prompt.len() as u64, "prefill step split wrong");
    }

    #[test]
    fn round_stepping_matches_step_once() {
        use crate::engine::RoundWork;
        // legacy: token-at-a-time lockstep
        let legacy: Vec<Vec<u32>> = {
            let mut eng = engine(4);
            let mut sessions: Vec<Session> = (1..=3u64)
                .map(|i| {
                    Session::new(i, &eng, &[i as u32, 2, 8], 5, Sampler::new(Sampling::Greedy, i))
                        .unwrap()
                })
                .collect();
            decode_lockstep(&mut eng, &mut sessions).unwrap();
            sessions.into_iter().map(|s| s.tokens).collect()
        };
        // round path: same lockstep rounds through ONE step_round each
        let mut eng = engine(4);
        let mut sessions: Vec<Session> = (1..=3u64)
            .map(|i| {
                Session::new(i, &eng, &[i as u32, 2, 8], 5, Sampler::new(Sampling::Greedy, i))
                    .unwrap()
            })
            .collect();
        loop {
            let feeds: Vec<(usize, u32, bool)> = sessions
                .iter()
                .enumerate()
                .filter(|(_, s)| !s.done)
                .map(|(i, s)| {
                    let (tok, gen) = s.peek_next();
                    (i, tok, gen)
                })
                .collect();
            if feeds.is_empty() {
                break;
            }
            let mut slots: Vec<Option<&mut Session>> = sessions.iter_mut().map(Some).collect();
            let mut work = Vec::new();
            for &(i, tok, gen) in &feeds {
                let s = slots[i].take().unwrap();
                work.push(RoundWork {
                    session: s.id,
                    tok,
                    pos: s.pos,
                    prefill: !gen,
                    degradable: false,
                    kv: &mut s.kv,
                });
            }
            let results = eng.step_round(&mut work);
            drop(work);
            drop(slots);
            // every round preserves the dedup identity
            assert_eq!(
                results.stats.batched_rows - results.stats.distinct_experts,
                results.stats.dedup_joins
            );
            for ((i, tok, gen), outcome) in feeds.into_iter().zip(results.outcomes) {
                sessions[i].apply_step(tok, gen, &outcome.unwrap());
            }
        }
        let round: Vec<Vec<u32>> = sessions.into_iter().map(|s| s.tokens).collect();
        assert_eq!(round, legacy, "round batching changed token streams");
        assert!(eng.round_batch_stats().rounds > 0);
    }

    #[test]
    fn round_dedup_counts_exact_for_identical_sessions() {
        use crate::engine::RoundWork;
        // identical prompts + greedy sampling → identical token streams →
        // identical routing: every distinct expert in a round receives one
        // row from EACH session, so the dedup counters are exact multiples
        let n = 3usize;
        let mut eng = engine(4);
        let mut sessions: Vec<Session> = (1..=n as u64)
            .map(|i| {
                Session::new(i, &eng, &[3, 2, 8], 5, Sampler::new(Sampling::Greedy, i)).unwrap()
            })
            .collect();
        loop {
            let feeds: Vec<(usize, u32, bool)> = sessions
                .iter()
                .enumerate()
                .filter(|(_, s)| !s.done)
                .map(|(i, s)| {
                    let (tok, gen) = s.peek_next();
                    (i, tok, gen)
                })
                .collect();
            if feeds.is_empty() {
                break;
            }
            let mut slots: Vec<Option<&mut Session>> = sessions.iter_mut().map(Some).collect();
            let mut work = Vec::new();
            for &(i, tok, gen) in &feeds {
                let s = slots[i].take().unwrap();
                work.push(RoundWork {
                    session: s.id,
                    tok,
                    pos: s.pos,
                    prefill: !gen,
                    degradable: false,
                    kv: &mut s.kv,
                });
            }
            let results = eng.step_round(&mut work);
            drop(work);
            drop(slots);
            for ((i, tok, gen), outcome) in feeds.into_iter().zip(results.outcomes) {
                sessions[i].apply_step(tok, gen, &outcome.unwrap());
            }
        }
        let stats = eng.round_batch_stats();
        assert!(stats.distinct_experts > 0);
        assert_eq!(stats.batched_rows, stats.distinct_experts * n as u64);
        assert_eq!(stats.dedup_joins, stats.distinct_experts * (n as u64 - 1));
        // per-session tallies still partition the shared cache's totals
        let total = eng.cache_stats();
        let (mut hits, mut misses) = (0, 0);
        for i in 1..=n as u64 {
            let t = eng.session_tally(i);
            hits += t.hits;
            misses += t.misses;
        }
        assert_eq!(hits, total.hits);
        assert_eq!(misses, total.misses);
    }

    #[test]
    fn session_rejects_bad_inputs() {
        let eng = engine(4);
        assert!(Session::new(0, &eng, &[], 4, Sampler::new(Sampling::Greedy, 0)).is_err());
        let long = vec![1u32; ModelConfig::TINY.max_seq + 1];
        assert!(Session::new(0, &eng, &long, 0, Sampler::new(Sampling::Greedy, 0)).is_err());
    }
}
