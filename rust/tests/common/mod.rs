//! Shared deterministic serve-test harness.
//!
//! Two tools replace wall-clock guesswork in the serve suites:
//!
//! * [`wait_until`] — deadline polling: spin a predicate until it holds
//!   or a generous deadline passes. Assertions express *what* must
//!   eventually be true, never *how fast* the machine is.
//! * [`Pace`] + [`PacedBackend`] — a test backend whose per-token step is
//!   gated on explicitly granted permits and stamped on a
//!   `util::simclock::SimClock` (virtual seconds), instead of a
//!   `std::thread::sleep` per step. Tests grant an exact number of steps,
//!   wait for the engine to consume them (it then blocks, so `/metrics`
//!   quiesces), and make race-free assertions about mid-flight state:
//!   "after ≤ N engine steps, X holds" is machine-speed independent.
//!
//! Included via `mod common;` from each integration-test crate; not every
//! crate uses every item, hence the file-level `allow(dead_code)`.
#![allow(dead_code)]

use moe_offload::cache::PolicyKind;
use moe_offload::engine::{EngineConfig, InferenceEngine};
use moe_offload::model::weights::generate_weights;
use moe_offload::model::ModelConfig;
use moe_offload::offload::store::HostExpertStore;
use moe_offload::offload::transfer::FaultPlan;
use moe_offload::quant::Scheme;
use moe_offload::runtime::native::NativeBackend;
use moe_offload::runtime::{Backend, ExpertHandle, KvState};
use moe_offload::util::simclock::SimClock;
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// Poll `pred` every couple of milliseconds until it returns true or
/// `deadline` elapses; returns the predicate's final verdict. Use a
/// generous deadline — it only bounds how long a FAILING test takes.
pub fn wait_until(mut pred: impl FnMut() -> bool, deadline: Duration) -> bool {
    let t0 = Instant::now();
    loop {
        if pred() {
            return true;
        }
        if t0.elapsed() > deadline {
            return pred();
        }
        std::thread::sleep(Duration::from_millis(2));
    }
}

struct PaceState {
    /// Steps the engine may still take; `None` = unlimited (opened).
    permits: Option<u64>,
    /// Steps taken so far.
    consumed: u64,
    /// Virtual time: one fixed `dt` per engine step.
    clock: SimClock,
}

/// Step-permit gate + virtual clock shared between a test and its
/// [`PacedBackend`]. Starts closed (zero permits): the engine blocks on
/// its first token until the test grants steps, so admission/queue state
/// can be arranged with ZERO decode progress in between.
pub struct Pace {
    state: Mutex<PaceState>,
    granted: Condvar,
    /// Virtual seconds charged per engine step.
    pub dt: f64,
}

impl Pace {
    pub fn new() -> Arc<Pace> {
        Arc::new(Pace {
            state: Mutex::new(PaceState {
                permits: Some(0),
                consumed: 0,
                clock: SimClock::new(),
            }),
            granted: Condvar::new(),
            dt: 1.0,
        })
    }

    /// Allow `n` more engine steps.
    pub fn grant(&self, n: u64) {
        let mut st = self.state.lock().unwrap();
        if let Some(p) = &mut st.permits {
            *p += n;
        }
        self.granted.notify_all();
    }

    /// Remove the gate entirely: the engine runs freely from here on.
    pub fn open(&self) {
        self.state.lock().unwrap().permits = None;
        self.granted.notify_all();
    }

    /// Open the pace when the returned guard drops — declare it right
    /// AFTER the `Server` so an assertion failure (unwind) releases the
    /// engine before the server's drop joins its threads.
    pub fn open_on_drop(pace: &Arc<Pace>) -> OpenOnDrop {
        OpenOnDrop(Arc::clone(pace))
    }

    /// Engine steps taken so far.
    pub fn consumed(&self) -> u64 {
        self.state.lock().unwrap().consumed
    }

    /// Virtual time consumed by the engine, in simulated seconds.
    pub fn sim_now(&self) -> f64 {
        self.state.lock().unwrap().clock.now()
    }

    /// Called by [`PacedBackend`] once per token step: block until a
    /// permit is available (or the gate is open), then consume it and
    /// advance the virtual clock.
    fn step(&self) {
        let mut st = self.state.lock().unwrap();
        while st.permits == Some(0) {
            st = self.granted.wait(st).unwrap();
        }
        if let Some(p) = &mut st.permits {
            *p -= 1;
        }
        st.consumed += 1;
        let dt = self.dt;
        st.clock.advance(dt);
    }
}

/// Releases the [`Pace`] gate on drop (including on panic/unwind).
pub struct OpenOnDrop(Arc<Pace>);

impl Drop for OpenOnDrop {
    fn drop(&mut self) {
        self.0.open();
    }
}

/// A native backend whose per-token step is gated by a [`Pace`] instead
/// of slowed by `std::thread::sleep`: tests decide exactly how many
/// steps the engine may take and read virtual time off the pace's
/// `SimClock`. `embed` runs exactly once per token step — the one choke
/// point, same as the legacy `SlowBackend`.
pub struct PacedBackend {
    inner: NativeBackend,
    pace: Arc<Pace>,
}

impl Backend for PacedBackend {
    fn config(&self) -> &ModelConfig {
        self.inner.config()
    }
    fn new_kv(&self) -> anyhow::Result<KvState> {
        self.inner.new_kv()
    }
    fn embed(&self, tok: u32) -> anyhow::Result<Vec<f32>> {
        self.pace.step();
        self.inner.embed(tok)
    }
    fn attn(
        &self,
        layer: usize,
        x: &[f32],
        kv: &mut KvState,
        pos: usize,
    ) -> anyhow::Result<Vec<f32>> {
        self.inner.attn(layer, x, kv, pos)
    }
    fn router(&self, layer: usize, x_res: &[f32]) -> anyhow::Result<(Vec<f32>, Vec<f32>)> {
        self.inner.router(layer, x_res)
    }
    fn spec_router(&self, layer: usize, x_res: &[f32]) -> anyhow::Result<Vec<f32>> {
        self.inner.spec_router(layer, x_res)
    }
    fn expert(&self, h: &[f32], handle: &ExpertHandle) -> anyhow::Result<Vec<f32>> {
        self.inner.expert(h, handle)
    }
    fn begin_round(&self) {
        self.inner.begin_round()
    }
    fn expert_multi(
        &self,
        layer: usize,
        expert: usize,
        sessions: &[u64],
        hs: &[&[f32]],
        handle: &ExpertHandle,
    ) -> anyhow::Result<Vec<Vec<f32>>> {
        // forward to the inner backend's scratch-reusing implementation —
        // the pace gates per-token progress at `embed`, not per expert
        self.inner.expert_multi(layer, expert, sessions, hs, handle)
    }
    fn upload_expert(
        &self,
        w1: Vec<f32>,
        w3: Vec<f32>,
        w2: Vec<f32>,
    ) -> anyhow::Result<ExpertHandle> {
        self.inner.upload_expert(w1, w3, w2)
    }
    fn final_logits(&self, x: &[f32]) -> anyhow::Result<Vec<f32>> {
        self.inner.final_logits(x)
    }
    fn name(&self) -> &'static str {
        "native-paced"
    }
}

/// One batched expert pass as observed by a [`RoundRecorder`]: the
/// `(layer, expert)` group and the sessions whose rows it carried, in
/// arrival order.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct BatchedPass {
    pub layer: usize,
    pub expert: usize,
    pub sessions: Vec<u64>,
}

enum RecEntry {
    /// A `begin_round` boundary — starts a new round segment.
    Round,
    Pass(BatchedPass),
}

/// Backend wrapper recording every round boundary and batched expert pass
/// (the round-shape observability layer): wraps any [`Backend`], forwards
/// all math untouched, and logs `(layer, expert, sessions)` per
/// `expert_multi` call segmented by `begin_round`. Reused across unit,
/// integration, and property tests to assert the round-batching shape —
/// at most ONE batched pass per distinct `(layer, expert)` per round
/// ([`assert_round_shape`]).
pub struct RoundRecorder<B: Backend> {
    inner: B,
    log: Arc<Mutex<Vec<RecEntry>>>,
}

impl<B: Backend> RoundRecorder<B> {
    pub fn new(inner: B) -> RoundRecorder<B> {
        RoundRecorder { inner, log: Arc::new(Mutex::new(Vec::new())) }
    }

    /// Handle to the shared log, to read rounds back after the engine
    /// (which owns the backend) has been moved away.
    pub fn log_handle(&self) -> RoundLog {
        RoundLog(Arc::clone(&self.log))
    }
}

/// Cloneable read/drain handle onto a [`RoundRecorder`]'s log.
#[derive(Clone)]
pub struct RoundLog(Arc<Mutex<Vec<RecEntry>>>);

impl RoundLog {
    /// Drain the log into per-round segments of batched passes (one
    /// segment per `begin_round`; passes before the first boundary — e.g.
    /// from non-round engine paths — land in a leading segment).
    pub fn take_rounds(&self) -> Vec<Vec<BatchedPass>> {
        let mut entries = self.0.lock().unwrap();
        let mut rounds = vec![Vec::new()];
        for e in entries.drain(..) {
            match e {
                RecEntry::Round => rounds.push(Vec::new()),
                RecEntry::Pass(p) => rounds.last_mut().unwrap().push(p),
            }
        }
        if rounds.first().is_some_and(|r| r.is_empty()) {
            rounds.remove(0);
        }
        rounds
    }
}

/// The round-shape invariant: within one round, each distinct
/// `(layer, expert)` is executed by at most ONE batched pass — dedup
/// happened before dispatch, never after.
pub fn assert_round_shape(passes: &[BatchedPass]) {
    let mut seen: Vec<(usize, usize)> = Vec::new();
    for p in passes {
        assert!(
            !seen.contains(&(p.layer, p.expert)),
            "round executed (layer {}, expert {}) in more than one batched pass",
            p.layer,
            p.expert
        );
        assert!(!p.sessions.is_empty(), "batched pass with no rows");
        seen.push((p.layer, p.expert));
    }
}

impl<B: Backend> Backend for RoundRecorder<B> {
    fn config(&self) -> &ModelConfig {
        self.inner.config()
    }
    fn new_kv(&self) -> anyhow::Result<KvState> {
        self.inner.new_kv()
    }
    fn embed(&self, tok: u32) -> anyhow::Result<Vec<f32>> {
        self.inner.embed(tok)
    }
    fn attn(
        &self,
        layer: usize,
        x: &[f32],
        kv: &mut KvState,
        pos: usize,
    ) -> anyhow::Result<Vec<f32>> {
        self.inner.attn(layer, x, kv, pos)
    }
    fn router(&self, layer: usize, x_res: &[f32]) -> anyhow::Result<(Vec<f32>, Vec<f32>)> {
        self.inner.router(layer, x_res)
    }
    fn spec_router(&self, layer: usize, x_res: &[f32]) -> anyhow::Result<Vec<f32>> {
        self.inner.spec_router(layer, x_res)
    }
    fn expert(&self, h: &[f32], handle: &ExpertHandle) -> anyhow::Result<Vec<f32>> {
        self.inner.expert(h, handle)
    }
    fn begin_round(&self) {
        self.log.lock().unwrap().push(RecEntry::Round);
        self.inner.begin_round()
    }
    fn expert_multi(
        &self,
        layer: usize,
        expert: usize,
        sessions: &[u64],
        hs: &[&[f32]],
        handle: &ExpertHandle,
    ) -> anyhow::Result<Vec<Vec<f32>>> {
        self.log.lock().unwrap().push(RecEntry::Pass(BatchedPass {
            layer,
            expert,
            sessions: sessions.to_vec(),
        }));
        self.inner.expert_multi(layer, expert, sessions, hs, handle)
    }
    fn upload_expert(
        &self,
        w1: Vec<f32>,
        w3: Vec<f32>,
        w2: Vec<f32>,
    ) -> anyhow::Result<ExpertHandle> {
        self.inner.upload_expert(w1, w3, w2)
    }
    fn final_logits(&self, x: &[f32]) -> anyhow::Result<Vec<f32>> {
        self.inner.final_logits(x)
    }
    fn name(&self) -> &'static str {
        "round-recorder"
    }
}

/// Byte-tokenizer-compatible tiny config (vocab must hold 256 bytes +
/// specials), shared by the serve-layer integration tests.
pub fn serve_model_config() -> ModelConfig {
    ModelConfig { vocab_size: 320, max_seq: 96, ..ModelConfig::TINY }
}

/// Engine over a [`PacedBackend`]: every per-token step consumes one
/// permit from `pace`.
pub fn paced_engine(
    pace: Arc<Pace>,
    transfer_workers: usize,
) -> anyhow::Result<InferenceEngine> {
    let store = serve_store()?;
    paced_engine_with_store(pace, transfer_workers, store)
}

/// The host expert store the paced serve harness uses (seed 42, F32).
/// Build it ONCE and pass the same `Arc` to several
/// [`paced_engine_with_store`] calls to get the multi-replica topology:
/// per-replica engines/device caches over one shared host store.
pub fn serve_store() -> anyhow::Result<Arc<HostExpertStore>> {
    let weights = Arc::new(generate_weights(serve_model_config(), 42));
    Ok(Arc::new(HostExpertStore::build(&weights, Scheme::F32)?))
}

/// [`paced_engine`] over a caller-provided host store (shared-store
/// multi-replica tests pass the same `Arc` to every replica's engine).
pub fn paced_engine_with_store(
    pace: Arc<Pace>,
    transfer_workers: usize,
    store: Arc<HostExpertStore>,
) -> anyhow::Result<InferenceEngine> {
    let weights = Arc::new(generate_weights(serve_model_config(), 42));
    let mut cfg = EngineConfig::serving(4, PolicyKind::Lfu, false);
    cfg.transfer_workers = transfer_workers;
    Ok(InferenceEngine::new(
        Box::new(PacedBackend { inner: NativeBackend::new(weights), pace }),
        store,
        cfg,
    ))
}

/// Remote kill switch for replica-death tests: once flipped, the paired
/// [`KillablePacedBackend`] panics at its next per-token step — modelling
/// an engine worker dying mid-decode. The panic unwinds through the
/// scheduler loop (its `ActiveSet` answers in-flight sessions with 500s)
/// into the serve worker guard (which quarantines the replica).
#[derive(Clone, Default)]
pub struct KillSwitch(Arc<std::sync::atomic::AtomicBool>);

impl KillSwitch {
    pub fn new() -> KillSwitch {
        KillSwitch::default()
    }

    pub fn kill(&self) {
        self.0.store(true, std::sync::atomic::Ordering::SeqCst);
    }

    pub fn is_killed(&self) -> bool {
        self.0.load(std::sync::atomic::Ordering::SeqCst)
    }
}

/// A [`PacedBackend`] that panics at the next token step once its
/// [`KillSwitch`] flips. The kill check runs BEFORE the pace gate so a
/// killed replica dies even when no permits are outstanding.
pub struct KillablePacedBackend {
    inner: PacedBackend,
    kill: KillSwitch,
}

impl Backend for KillablePacedBackend {
    fn config(&self) -> &ModelConfig {
        self.inner.config()
    }
    fn new_kv(&self) -> anyhow::Result<KvState> {
        self.inner.new_kv()
    }
    fn embed(&self, tok: u32) -> anyhow::Result<Vec<f32>> {
        if self.kill.is_killed() {
            panic!("injected replica kill");
        }
        self.inner.embed(tok)
    }
    fn attn(
        &self,
        layer: usize,
        x: &[f32],
        kv: &mut KvState,
        pos: usize,
    ) -> anyhow::Result<Vec<f32>> {
        self.inner.attn(layer, x, kv, pos)
    }
    fn router(&self, layer: usize, x_res: &[f32]) -> anyhow::Result<(Vec<f32>, Vec<f32>)> {
        self.inner.router(layer, x_res)
    }
    fn spec_router(&self, layer: usize, x_res: &[f32]) -> anyhow::Result<Vec<f32>> {
        self.inner.spec_router(layer, x_res)
    }
    fn expert(&self, h: &[f32], handle: &ExpertHandle) -> anyhow::Result<Vec<f32>> {
        self.inner.expert(h, handle)
    }
    fn begin_round(&self) {
        self.inner.begin_round()
    }
    fn expert_multi(
        &self,
        layer: usize,
        expert: usize,
        sessions: &[u64],
        hs: &[&[f32]],
        handle: &ExpertHandle,
    ) -> anyhow::Result<Vec<Vec<f32>>> {
        self.inner.expert_multi(layer, expert, sessions, hs, handle)
    }
    fn upload_expert(
        &self,
        w1: Vec<f32>,
        w3: Vec<f32>,
        w2: Vec<f32>,
    ) -> anyhow::Result<ExpertHandle> {
        self.inner.upload_expert(w1, w3, w2)
    }
    fn final_logits(&self, x: &[f32]) -> anyhow::Result<Vec<f32>> {
        self.inner.final_logits(x)
    }
    fn name(&self) -> &'static str {
        "native-paced-killable"
    }
}

/// [`paced_engine_with_store`] whose backend dies when `kill` flips —
/// the replica-kill fault harness.
pub fn killable_paced_engine(
    pace: Arc<Pace>,
    transfer_workers: usize,
    store: Arc<HostExpertStore>,
    kill: KillSwitch,
) -> anyhow::Result<InferenceEngine> {
    let weights = Arc::new(generate_weights(serve_model_config(), 42));
    let mut cfg = EngineConfig::serving(4, PolicyKind::Lfu, false);
    cfg.transfer_workers = transfer_workers;
    Ok(InferenceEngine::new(
        Box::new(KillablePacedBackend {
            inner: PacedBackend { inner: NativeBackend::new(weights), pace },
            kill,
        }),
        store,
        cfg,
    ))
}

/// Engine with a seeded [`FaultPlan`] injected on its transfer engine, so
/// integration tests can script per-`(layer, expert)` delays, transient
/// fetch failures, and permanent failures deterministically (e.g. "expert
/// (l, e) fails twice then succeeds", "expert (l, e) stalls 50 virtual
/// ms"). `tweak` adjusts the serving config (deadline, retry budget)
/// before construction.
pub fn faulty_engine(
    plan: FaultPlan,
    transfer_workers: usize,
    tweak: impl FnOnce(&mut EngineConfig),
) -> anyhow::Result<InferenceEngine> {
    let weights = Arc::new(generate_weights(serve_model_config(), 42));
    let store = Arc::new(HostExpertStore::build(&weights, Scheme::F32)?);
    let mut cfg = EngineConfig::serving(4, PolicyKind::Lfu, false);
    cfg.transfer_workers = transfer_workers;
    tweak(&mut cfg);
    let mut engine =
        InferenceEngine::new(Box::new(NativeBackend::new(weights)), store, cfg);
    engine.inject_faults(plan);
    Ok(engine)
}

/// [`paced_engine`] with a [`FaultPlan`] injected on top — permit-gated
/// steps AND scripted transfer faults in one deterministic harness.
pub fn paced_engine_with_faults(
    pace: Arc<Pace>,
    transfer_workers: usize,
    plan: FaultPlan,
    tweak: impl FnOnce(&mut EngineConfig),
) -> anyhow::Result<InferenceEngine> {
    let weights = Arc::new(generate_weights(serve_model_config(), 42));
    let store = Arc::new(HostExpertStore::build(&weights, Scheme::F32)?);
    let mut cfg = EngineConfig::serving(4, PolicyKind::Lfu, false);
    cfg.transfer_workers = transfer_workers;
    tweak(&mut cfg);
    let mut engine = InferenceEngine::new(
        Box::new(PacedBackend { inner: NativeBackend::new(weights), pace }),
        store,
        cfg,
    );
    engine.inject_faults(plan);
    Ok(engine)
}
