//! Serve-layer integration tests: real TCP listener on an ephemeral port,
//! concurrent `POST /generate` clients, and `/metrics` assertions.
//!
//! The key property under test is the ISSUE's acceptance criterion: N ≥ 4
//! concurrent sessions decode over ONE shared expert cache (the `/metrics`
//! `shared_cache` object is singular and the per-session counters partition
//! its totals), and a bounded queue applies backpressure with HTTP 503.

use moe_offload::cache::PolicyKind;
use moe_offload::engine::{EngineConfig, InferenceEngine};
use moe_offload::model::weights::generate_weights;
use moe_offload::model::ModelConfig;
use moe_offload::offload::store::HostExpertStore;
use moe_offload::quant::Scheme;
use moe_offload::runtime::native::NativeBackend;
use moe_offload::serve::http::{client_get as http_get, client_post as http_post};
use moe_offload::serve::{self, ServeConfig};
use moe_offload::util::json;
use std::net::{SocketAddr, TcpListener};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Barrier};

/// Vocab must hold 256 bytes + specials for the byte tokenizer; the rest
/// stays TINY-sized so debug-mode tests are fast.
fn serve_config() -> ModelConfig {
    ModelConfig { vocab_size: 320, max_seq: 96, ..ModelConfig::TINY }
}

fn make_engine(spec: bool) -> anyhow::Result<InferenceEngine> {
    let weights = Arc::new(generate_weights(serve_config(), 42));
    let store = Arc::new(HostExpertStore::build(&weights, Scheme::F32)?);
    Ok(InferenceEngine::new(
        Box::new(NativeBackend::new(weights)),
        store,
        EngineConfig::serving(4, PolicyKind::Lfu, spec),
    ))
}

struct Server {
    addr: SocketAddr,
    shutdown: Arc<AtomicBool>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl Server {
    fn start(cfg: ServeConfig, spec: bool) -> Server {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let shutdown = Arc::new(AtomicBool::new(false));
        let sd = Arc::clone(&shutdown);
        let handle = std::thread::spawn(move || {
            serve::serve(listener, move || make_engine(spec), cfg, sd).unwrap();
        });
        let server = Server { addr, shutdown, handle: Some(handle) };
        server.wait_healthy();
        server
    }

    fn wait_healthy(&self) {
        for _ in 0..200 {
            if let Ok((200, _)) = http_get(self.addr, "/healthz") {
                return;
            }
            std::thread::sleep(std::time::Duration::from_millis(10));
        }
        panic!("server never became healthy");
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.shutdown.store(true, Ordering::Relaxed);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

#[test]
fn concurrent_sessions_share_one_cache() {
    let n_clients = 6usize;
    let n_tokens = 6usize;
    let server = Server::start(
        ServeConfig { http_workers: n_clients, max_sessions: 4, queue_depth: 16 },
        true,
    );

    // fire all clients at once so ≥4 sessions overlap on the scheduler
    let barrier = Arc::new(Barrier::new(n_clients));
    let addr = server.addr;
    let handles: Vec<_> = (0..n_clients)
        .map(|i| {
            let barrier = Arc::clone(&barrier);
            std::thread::spawn(move || {
                barrier.wait();
                let body = format!(
                    r#"{{"prompt":"concurrent prompt {i}","n_tokens":{n_tokens},"greedy":true}}"#
                );
                http_post(addr, "/generate", &body).unwrap()
            })
        })
        .collect();

    let mut session_ids = Vec::new();
    for h in handles {
        let (status, body) = h.join().unwrap();
        assert_eq!(status, 200, "body: {body}");
        let v = json::parse(&body).unwrap();
        assert_eq!(v.get("n_generated").as_usize(), Some(n_tokens));
        assert!(v.get("session_hits").as_usize().is_some());
        let id = v.get("session_id").as_usize().unwrap();
        assert!((1..=n_clients).contains(&id), "session id {id}");
        assert!(!session_ids.contains(&id), "duplicate session id {id}");
        session_ids.push(id);
    }

    let (status, body) = http_get(addr, "/metrics").unwrap();
    assert_eq!(status, 200);
    let m = json::parse(&body).unwrap();
    assert_eq!(m.get("completed_sessions").as_usize(), Some(n_clients));
    assert_eq!(m.get("active_sessions").as_usize(), Some(0));
    assert_eq!(
        m.get("tokens_generated").as_usize(),
        Some(n_clients * n_tokens)
    );

    // exactly one shared cache, multi-session counters partition it
    let cache = m.get("shared_cache");
    assert_eq!(cache.get("policy").as_str(), Some("lfu"));
    assert_eq!(cache.get("capacity_per_layer").as_usize(), Some(4));
    let total = cache.get("hits").as_usize().unwrap() + cache.get("misses").as_usize().unwrap();
    let sessions = m.get("sessions").as_arr().unwrap();
    assert_eq!(sessions.len(), n_clients, "all sessions visible in /metrics");
    let part: usize = sessions
        .iter()
        .map(|s| s.get("hits").as_usize().unwrap() + s.get("misses").as_usize().unwrap())
        .sum();
    assert_eq!(part, total, "per-session counters must partition the shared cache");
    for s in sessions {
        assert_eq!(s.get("state").as_str(), Some("done"));
        assert_eq!(s.get("tokens").as_usize(), Some(n_tokens + 1 + "concurrent prompt 0".len()));
    }

    // speculation ran and its per-guess cardinality identity held (§5.4)
    let spec = m.get("speculation");
    assert!(spec.get("tp").as_usize().unwrap() + spec.get("fp").as_usize().unwrap() > 0);
    assert_eq!(spec.get("fp").as_usize(), spec.get("fn").as_usize());
}

#[test]
fn bounded_queue_applies_backpressure() {
    // one decode slot + one queue slot: concurrent clients beyond the two
    // must be rejected with 503 while the first request decodes
    let server = Server::start(
        ServeConfig { http_workers: 8, max_sessions: 1, queue_depth: 1 },
        false,
    );
    let n_clients = 8usize;
    let barrier = Arc::new(Barrier::new(n_clients));
    let addr = server.addr;
    let handles: Vec<_> = (0..n_clients)
        .map(|i| {
            let barrier = Arc::clone(&barrier);
            std::thread::spawn(move || {
                barrier.wait();
                let body =
                    format!(r#"{{"prompt":"load {i}","n_tokens":64,"greedy":true}}"#);
                http_post(addr, "/generate", &body).unwrap()
            })
        })
        .collect();

    let mut ok = 0;
    let mut rejected = 0;
    for h in handles {
        match h.join().unwrap() {
            (200, _) => ok += 1,
            (503, body) => {
                assert!(body.contains("queue full"), "{body}");
                rejected += 1;
            }
            (status, body) => panic!("unexpected {status}: {body}"),
        }
    }
    assert_eq!(ok + rejected, n_clients);
    assert!(ok >= 1, "at least the first request must be served");
    assert!(rejected >= 1, "queue bound must reject overload");

    let (_, body) = http_get(addr, "/metrics").unwrap();
    let m = json::parse(&body).unwrap();
    assert_eq!(m.get("rejected_backpressure").as_usize(), Some(rejected));
    assert_eq!(m.get("completed_sessions").as_usize(), Some(ok));
}

#[test]
fn invalid_requests_are_rejected_cleanly() {
    let server = Server::start(ServeConfig::default(), false);
    let (status, body) = http_post(server.addr, "/generate", r#"{"n_tokens":4}"#).unwrap();
    assert_eq!(status, 400);
    assert!(body.contains("prompt"));
    // overlong request passes parsing but fails admission
    let (status, body) = http_post(
        server.addr,
        "/generate",
        r#"{"prompt":"x","n_tokens":4000,"greedy":true}"#,
    )
    .unwrap();
    assert_eq!(status, 400, "{body}");
    assert!(body.contains("max_seq"), "{body}");
    let (status, _) = http_get(server.addr, "/nope").unwrap();
    assert_eq!(status, 404);
}
