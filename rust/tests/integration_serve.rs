//! Serve-layer integration tests: real TCP listener on an ephemeral port,
//! concurrent `POST /generate` clients, and `/metrics` assertions.
//!
//! Three properties carry the suite:
//!
//! 1. N ≥ 4 concurrent sessions decode over ONE shared expert cache (the
//!    `/metrics` `shared_cache` object is singular and the per-session
//!    counters partition its totals).
//! 2. Overload is handled by *admission control*, not hidden buffering: at
//!    the DEFAULT `ServeConfig` (no tuned worker/queue ratio), a flood of
//!    slow decodes produces real 503s while the `queue_depth` gauge never
//!    exceeds its configured bound, every accepted request completes with
//!    exactly one 200, aged queued requests are shed with 503 +
//!    `Retry-After`, and `/metrics` stays responsive throughout — the
//!    completion-routed flow of DESIGN.md §6.
//! 3. Chunked prefill kills head-of-line blocking: with `--prefill-chunk`
//!    on, short sessions' first tokens land while a long prompt's prefill
//!    is still in progress (proven with a step-budget argument on the
//!    permit-gated `PacedBackend` — no wall-clock margins).
//! 4. The serve path is failure-aware end to end (DESIGN.md §9): streamed
//!    (`?stream=1`) and buffered completions are byte-identical, a client
//!    hang-up mid-decode cancels its session and frees its resources while
//!    survivors finish, and scripted transfer faults are absorbed by the
//!    retry/degrade ladder without failing a single session.
//!
//! Timing discipline (`tests/common/mod.rs`): assertions that depend on
//! engine progress either poll a deadline (`wait_until`) or gate the
//! engine on explicit step permits (`Pace`/`PacedBackend`, virtual time);
//! no assertion rests on a bare `sleep` margin.

mod common;

use common::{
    faulty_engine, killable_paced_engine, paced_engine, paced_engine_with_store, serve_store,
    wait_until, KillSwitch, Pace,
};
use moe_offload::cache::PolicyKind;
use moe_offload::engine::{EngineConfig, InferenceEngine};
use moe_offload::model::weights::generate_weights;
use moe_offload::model::ModelConfig;
use moe_offload::offload::store::HostExpertStore;
use moe_offload::offload::transfer::FaultPlan;
use moe_offload::quant::Scheme;
use moe_offload::runtime::native::NativeBackend;
use moe_offload::runtime::{Backend, ExpertHandle, KvState};
use moe_offload::serve::http::{
    client_get as http_get, client_post as http_post, client_post_stream,
    client_post_text as http_post_text,
};
use moe_offload::serve::{self, ServeConfig};
use moe_offload::util::json::{self, Value};
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Barrier};
use std::time::{Duration, Instant};

fn serve_config() -> ModelConfig {
    common::serve_model_config()
}

fn make_engine(spec: bool) -> anyhow::Result<InferenceEngine> {
    let weights = Arc::new(generate_weights(serve_config(), 42));
    let store = Arc::new(HostExpertStore::build(&weights, Scheme::F32)?);
    Ok(InferenceEngine::new(
        Box::new(NativeBackend::new(weights)),
        store,
        EngineConfig::serving(4, PolicyKind::Lfu, spec),
    ))
}

/// A native backend whose per-token step is slowed by a fixed sleep, used
/// where the test WANTS wall-clock pressure (a real overload flood that
/// outpaces the drain rate). Tests whose assertions depend on exact
/// engine progress use `common::PacedBackend` instead.
struct SlowBackend {
    inner: NativeBackend,
    step_delay: Duration,
}

impl Backend for SlowBackend {
    fn config(&self) -> &ModelConfig {
        self.inner.config()
    }
    fn new_kv(&self) -> anyhow::Result<KvState> {
        self.inner.new_kv()
    }
    fn embed(&self, tok: u32) -> anyhow::Result<Vec<f32>> {
        // embed runs exactly once per token step — the one choke point
        std::thread::sleep(self.step_delay);
        self.inner.embed(tok)
    }
    fn attn(
        &self,
        layer: usize,
        x: &[f32],
        kv: &mut KvState,
        pos: usize,
    ) -> anyhow::Result<Vec<f32>> {
        self.inner.attn(layer, x, kv, pos)
    }
    fn router(&self, layer: usize, x_res: &[f32]) -> anyhow::Result<(Vec<f32>, Vec<f32>)> {
        self.inner.router(layer, x_res)
    }
    fn spec_router(&self, layer: usize, x_res: &[f32]) -> anyhow::Result<Vec<f32>> {
        self.inner.spec_router(layer, x_res)
    }
    fn expert(&self, h: &[f32], handle: &ExpertHandle) -> anyhow::Result<Vec<f32>> {
        self.inner.expert(h, handle)
    }
    fn upload_expert(
        &self,
        w1: Vec<f32>,
        w3: Vec<f32>,
        w2: Vec<f32>,
    ) -> anyhow::Result<ExpertHandle> {
        self.inner.upload_expert(w1, w3, w2)
    }
    fn final_logits(&self, x: &[f32]) -> anyhow::Result<Vec<f32>> {
        self.inner.final_logits(x)
    }
    fn name(&self) -> &'static str {
        "native-slow"
    }
}

fn make_slow_engine(
    step_delay: Duration,
    transfer_workers: usize,
) -> anyhow::Result<InferenceEngine> {
    let weights = Arc::new(generate_weights(serve_config(), 42));
    let store = Arc::new(HostExpertStore::build(&weights, Scheme::F32)?);
    let mut cfg = EngineConfig::serving(4, PolicyKind::Lfu, false);
    cfg.transfer_workers = transfer_workers;
    Ok(InferenceEngine::new(
        Box::new(SlowBackend { inner: NativeBackend::new(weights), step_delay }),
        store,
        cfg,
    ))
}

struct Server {
    addr: SocketAddr,
    shutdown: Arc<AtomicBool>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl Server {
    fn start(cfg: ServeConfig, spec: bool) -> Server {
        Server::start_with(cfg, move |_replica| make_engine(spec))
    }

    /// `make` is called once per engine replica (`cfg.engine_workers`
    /// times) with the replica id, so it must be `Fn`, not `FnOnce`.
    fn start_with<F>(cfg: ServeConfig, make: F) -> Server
    where
        F: Fn(usize) -> anyhow::Result<InferenceEngine> + Send + Sync + 'static,
    {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let shutdown = Arc::new(AtomicBool::new(false));
        let sd = Arc::clone(&shutdown);
        let handle = std::thread::spawn(move || {
            serve::serve(listener, make, cfg, sd).unwrap();
        });
        let server = Server { addr, shutdown, handle: Some(handle) };
        server.wait_healthy();
        server
    }

    fn wait_healthy(&self) {
        let addr = self.addr;
        assert!(
            wait_until(
                || matches!(http_get(addr, "/healthz"), Ok((200, _))),
                Duration::from_secs(5)
            ),
            "server never became healthy"
        );
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.shutdown.store(true, Ordering::Relaxed);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

fn fetch_metrics(addr: SocketAddr) -> Value {
    let (status, body) = http_get(addr, "/metrics").unwrap();
    assert_eq!(status, 200, "{body}");
    json::parse(&body).unwrap()
}

#[test]
fn concurrent_sessions_share_one_cache() {
    let n_clients = 6usize;
    let n_tokens = 6usize;
    let server = Server::start(
        ServeConfig {
            http_workers: n_clients,
            max_sessions: 4,
            queue_depth: 16,
            ..ServeConfig::default()
        },
        true,
    );

    // fire all clients at once so ≥4 sessions overlap on the scheduler
    let barrier = Arc::new(Barrier::new(n_clients));
    let addr = server.addr;
    let handles: Vec<_> = (0..n_clients)
        .map(|i| {
            let barrier = Arc::clone(&barrier);
            std::thread::spawn(move || {
                barrier.wait();
                let body = format!(
                    r#"{{"prompt":"concurrent prompt {i}","n_tokens":{n_tokens},"greedy":true}}"#
                );
                http_post(addr, "/generate", &body).unwrap()
            })
        })
        .collect();

    let mut session_ids = Vec::new();
    for h in handles {
        let (status, body) = h.join().unwrap();
        assert_eq!(status, 200, "body: {body}");
        let v = json::parse(&body).unwrap();
        assert_eq!(v.get("n_generated").as_usize(), Some(n_tokens));
        assert!(v.get("session_hits").as_usize().is_some());
        let id = v.get("session_id").as_usize().unwrap();
        assert!((1..=n_clients).contains(&id), "session id {id}");
        assert!(!session_ids.contains(&id), "duplicate session id {id}");
        session_ids.push(id);
    }

    // responders release in-flight slots AFTER writing the response the
    // clients just read — poll the gauge down instead of racing it
    assert!(
        wait_until(
            || fetch_metrics(addr).get("inflight_sessions").as_usize() == Some(0),
            Duration::from_secs(5)
        ),
        "in-flight slots never released"
    );
    let m = fetch_metrics(addr);
    assert_eq!(m.get("completed_sessions").as_usize(), Some(n_clients));
    assert_eq!(m.get("active_sessions").as_usize(), Some(0));
    assert_eq!(
        m.get("tokens_generated").as_usize(),
        Some(n_clients * n_tokens)
    );
    // prompt work is metered separately (BOS + one token per byte)
    let n_prompt = "concurrent prompt 0".len() + 1;
    assert_eq!(m.get("tokens_prefill").as_usize(), Some(n_clients * n_prompt));
    assert_eq!(m.get("prefill_backlog").as_usize(), Some(0));
    // every session crossed into decode exactly once
    assert_eq!(m.get("ttft_ns").get("count").as_usize(), Some(n_clients));
    assert_eq!(m.get("queue_wait_ns").get("count").as_usize(), Some(n_clients));

    // exactly one shared cache, multi-session counters partition it
    let cache = m.get("shared_cache");
    assert_eq!(cache.get("policy").as_str(), Some("lfu"));
    assert_eq!(cache.get("capacity_per_layer").as_usize(), Some(4));
    let total = cache.get("hits").as_usize().unwrap() + cache.get("misses").as_usize().unwrap();
    let sessions = m.get("sessions").as_arr().unwrap();
    assert_eq!(sessions.len(), n_clients, "all sessions visible in /metrics");
    let part: usize = sessions
        .iter()
        .map(|s| s.get("hits").as_usize().unwrap() + s.get("misses").as_usize().unwrap())
        .sum();
    assert_eq!(part, total, "per-session counters must partition the shared cache");
    for s in sessions {
        assert_eq!(s.get("state").as_str(), Some("done"));
        assert_eq!(s.get("tokens").as_usize(), Some(n_tokens + n_prompt));
    }

    // speculation ran and its per-guess cardinality identity held (§5.4)
    let spec = m.get("speculation");
    assert!(spec.get("tp").as_usize().unwrap() + spec.get("fp").as_usize().unwrap() > 0);
    assert_eq!(spec.get("fp").as_usize(), spec.get("fn").as_usize());
}

#[test]
fn bounded_queue_applies_backpressure() {
    // one decode slot + one queue slot: concurrent clients beyond the two
    // must be rejected with 503 while the first request decodes
    let server = Server::start(
        ServeConfig {
            http_workers: 8,
            max_sessions: 1,
            queue_depth: 1,
            ..ServeConfig::default()
        },
        false,
    );
    let n_clients = 8usize;
    let barrier = Arc::new(Barrier::new(n_clients));
    let addr = server.addr;
    let handles: Vec<_> = (0..n_clients)
        .map(|i| {
            let barrier = Arc::clone(&barrier);
            std::thread::spawn(move || {
                barrier.wait();
                let body =
                    format!(r#"{{"prompt":"load {i}","n_tokens":64,"greedy":true}}"#);
                http_post(addr, "/generate", &body).unwrap()
            })
        })
        .collect();

    let mut ok = 0;
    let mut rejected = 0;
    for h in handles {
        match h.join().unwrap() {
            (200, _) => ok += 1,
            (503, body) => {
                assert!(body.contains("queue full"), "{body}");
                rejected += 1;
            }
            (status, body) => panic!("unexpected {status}: {body}"),
        }
    }
    assert_eq!(ok + rejected, n_clients);
    assert!(ok >= 1, "at least the first request must be served");
    assert!(rejected >= 1, "queue bound must reject overload");

    let m = fetch_metrics(addr);
    assert_eq!(m.get("rejected_backpressure").as_usize(), Some(rejected));
    assert_eq!(m.get("rejected_total").as_usize(), Some(rejected));
    assert_eq!(m.get("completed_sessions").as_usize(), Some(ok));
}

/// The overload acceptance test: at the DEFAULT `ServeConfig` — no tuned
/// `http_workers > queue_depth` ratio — an overload burst of slow decodes
/// produces real 503s, the `queue_depth` gauge never exceeds its bound
/// (sampled live via `/metrics`, which must stay responsive during
/// saturation), and every accepted request completes with exactly one 200.
/// Runs across transfer-worker counts 0/1/3.
#[test]
fn overload_at_default_config_rejects_and_completes() {
    for transfer_workers in [0usize, 1, 3] {
        overload_run(transfer_workers, 1);
    }
}

/// The same overload flood against TWO engine replicas: exactly-once
/// completion (ok + rejected == clients, every 200 fully decoded) must
/// hold when N schedulers race to claim from the one admission queue,
/// and the per-replica admission counts in `/metrics` must partition the
/// merged total — both replicas demonstrably took work.
#[test]
fn overload_at_two_replicas_completes_exactly_once() {
    overload_run(0, 2);
}

fn overload_run(transfer_workers: usize, engine_workers: usize) {
    let cfg = ServeConfig { engine_workers, ..ServeConfig::default() };
    let bound = cfg.queue_depth;
    let n_clients = 90usize; // > queue_depth + max_sessions: overflow is structural
    let n_tokens = 6usize;
    let server = Server::start_with(cfg, move |_replica| {
        make_slow_engine(Duration::from_millis(2), transfer_workers)
    });
    let addr = server.addr;

    // /metrics monitor: samples the queue gauge throughout the flood —
    // both the bound check and the liveness check (a hung /metrics would
    // stall the monitor and fail the sample-count assertion below)
    let flood_done = Arc::new(AtomicBool::new(false));
    let samples = Arc::new(AtomicU64::new(0));
    let max_queue_depth = Arc::new(AtomicU64::new(0));
    let monitor = {
        let flood_done = Arc::clone(&flood_done);
        let samples = Arc::clone(&samples);
        let max_queue_depth = Arc::clone(&max_queue_depth);
        std::thread::spawn(move || {
            // deadline-poll until the flood settles; each poll is a live
            // /metrics sample
            let sampled = wait_until(
                || {
                    let m = fetch_metrics(addr);
                    let qd = m.get("queue_depth").as_usize().unwrap() as u64;
                    max_queue_depth.fetch_max(qd, Ordering::Relaxed);
                    samples.fetch_add(1, Ordering::Relaxed);
                    flood_done.load(Ordering::Relaxed)
                },
                Duration::from_secs(300),
            );
            assert!(sampled, "flood never settled within the monitor deadline");
        })
    };

    let barrier = Arc::new(Barrier::new(n_clients));
    let handles: Vec<_> = (0..n_clients)
        .map(|i| {
            let barrier = Arc::clone(&barrier);
            std::thread::spawn(move || {
                barrier.wait();
                let body = format!(r#"{{"prompt":"flood {i}","n_tokens":{n_tokens},"greedy":true}}"#);
                http_post(addr, "/generate", &body).unwrap()
            })
        })
        .collect();

    let mut ok = 0usize;
    let mut rejected = 0usize;
    for h in handles {
        match h.join().unwrap() {
            (200, body) => {
                let v = json::parse(&body).unwrap();
                assert_eq!(
                    v.get("n_generated").as_usize(),
                    Some(n_tokens),
                    "accepted request must decode fully"
                );
                ok += 1;
            }
            (503, body) => {
                assert!(
                    body.contains("queue full") || body.contains("in-flight"),
                    "unexpected 503 body: {body}"
                );
                rejected += 1;
            }
            (status, body) => panic!("unexpected {status}: {body}"),
        }
    }
    flood_done.store(true, Ordering::Relaxed);
    monitor.join().unwrap();

    assert_eq!(ok + rejected, n_clients, "every client got exactly one answer");
    assert!(
        rejected >= 1,
        "default config must produce real 503s under overload (workers={transfer_workers})"
    );
    assert!(ok >= 1, "some requests must be served");
    assert!(
        samples.load(Ordering::Relaxed) >= 5,
        "/metrics starved during overload (workers={transfer_workers})"
    );
    assert!(
        max_queue_depth.load(Ordering::Relaxed) <= bound as u64,
        "queue_depth gauge exceeded its bound: {} > {bound}",
        max_queue_depth.load(Ordering::Relaxed)
    );

    // responders release slots after the clients read their responses:
    // poll the gauges down, then check the exactly-once accounting
    assert!(
        wait_until(
            || {
                let m = fetch_metrics(addr);
                m.get("queue_depth").as_usize() == Some(0)
                    && m.get("inflight_sessions").as_usize() == Some(0)
            },
            Duration::from_secs(10)
        ),
        "queue/inflight gauges never drained (workers={transfer_workers})"
    );
    let m = fetch_metrics(addr);
    assert_eq!(m.get("completed_sessions").as_usize(), Some(ok));
    assert_eq!(m.get("rejected_total").as_usize(), Some(rejected));
    assert_eq!(m.get("tokens_generated").as_usize(), Some(ok * n_tokens));
    assert_eq!(m.get("shed_total").as_usize(), Some(0), "no shedding at default config");
    assert_eq!(m.get("failed_sessions").as_usize(), Some(0));

    // replica accounting: the per-replica rows partition the merged totals
    assert_eq!(m.get("engine_replicas_alive").as_usize(), Some(engine_workers));
    let replicas = m.get("replicas").as_arr().unwrap();
    assert_eq!(replicas.len(), engine_workers);
    let completed_by_replica: usize = replicas
        .iter()
        .map(|r| r.get("completed_sessions").as_usize().unwrap())
        .sum();
    assert_eq!(completed_by_replica, ok, "per-replica completions must partition the total");
    let admitted_by_replica: usize =
        replicas.iter().map(|r| r.get("admitted").as_usize().unwrap()).sum();
    assert_eq!(admitted_by_replica, ok, "every admitted session completed exactly once");
    if engine_workers > 1 {
        // least-loaded routing under a 90-client flood: an idle replica is
        // always at minimum load, so both MUST have claimed work
        for r in replicas {
            assert!(
                r.get("admitted").as_usize().unwrap() >= 1,
                "a replica sat out the flood: {m:?}"
            );
        }
    }
}

/// Queue-age shedding, deterministically: the single decode slot is held
/// by a session on a permit-gated `PacedBackend`, so how long it stays
/// busy is measured in granted steps (≥ 2 ms each), not machine speed —
/// the queued waiters MUST age past `--queue-timeout-ms` and be shed with
/// 503 + `Retry-After` before consuming a single engine step.
#[test]
fn queue_timeout_sheds_with_retry_after() {
    let n_waiters = 4usize;
    let long_tokens = 72usize;
    let pace = Pace::new();
    let pace_engine = Arc::clone(&pace);
    let server = Server::start_with(
        ServeConfig {
            max_sessions: 1,
            queue_depth: 8,
            queue_timeout_ms: 75,
            ..ServeConfig::default()
        },
        move |_replica| paced_engine(Arc::clone(&pace_engine), 0),
    );
    // declared after `server`: drops first on any unwind, releasing the
    // engine so the server's own drop can join its threads
    let _open = Pace::open_on_drop(&pace);
    let addr = server.addr;

    let holder = std::thread::spawn(move || {
        let body =
            format!(r#"{{"prompt":"hold the slot","n_tokens":{long_tokens},"greedy":true}}"#);
        http_post(addr, "/generate", &body).unwrap()
    });
    // no engine steps yet: wait for the holder to be accepted (the
    // in-flight gauge is set at admission, before any decode)
    assert!(
        wait_until(
            || fetch_metrics(addr).get("inflight_sessions").as_usize() == Some(1),
            Duration::from_secs(10)
        ),
        "holder never admitted"
    );
    // grant single steps until the scheduler publishes the holder as the
    // active session occupying the one decode slot
    assert!(
        wait_until(
            || {
                pace.grant(1);
                fetch_metrics(addr).get("active_sessions").as_usize() == Some(1)
            },
            Duration::from_secs(10)
        ),
        "holder never became active"
    );

    let waiters: Vec<_> = (0..n_waiters)
        .map(|i| {
            std::thread::spawn(move || {
                let body = format!(r#"{{"prompt":"waiter {i}","n_tokens":4,"greedy":true}}"#);
                http_post_text(addr, "/generate", &body).unwrap()
            })
        })
        .collect();
    // all four queued behind the busy slot before any of them can age
    assert!(
        wait_until(
            || fetch_metrics(addr).get("queue_depth").as_usize() == Some(n_waiters),
            Duration::from_secs(10)
        ),
        "waiters never queued"
    );

    // drip one engine step per poll (≥ 2 ms apart): rounds — and their
    // shed sweeps — keep cycling while the holder's remaining ≥ 80 steps
    // keep the slot busy for ≥ 160 ms, far past the 75 ms queue timeout
    assert!(
        wait_until(
            || {
                pace.grant(1);
                waiters.iter().all(|w| w.is_finished())
            },
            Duration::from_secs(60)
        ),
        "waiters never answered"
    );
    let mut shed = 0usize;
    for w in waiters {
        let raw = w.join().unwrap();
        assert!(raw.starts_with("HTTP/1.1 503"), "waiter should be shed: {raw}");
        assert!(raw.contains("\r\nRetry-After:"), "shed 503 must carry Retry-After: {raw}");
        assert!(raw.contains("shed"), "{raw}");
        shed += 1;
    }
    // release the engine so the holder finishes
    pace.open();
    let (status, body) = holder.join().unwrap();
    assert_eq!(status, 200, "the admitted request completes: {body}");
    let v = json::parse(&body).unwrap();
    assert_eq!(v.get("n_generated").as_usize(), Some(long_tokens));

    assert!(
        wait_until(
            || fetch_metrics(addr).get("inflight_sessions").as_usize() == Some(0),
            Duration::from_secs(5)
        ),
        "in-flight slots never released"
    );
    let m = fetch_metrics(addr);
    assert_eq!(m.get("shed_total").as_usize(), Some(shed));
    assert_eq!(m.get("completed_sessions").as_usize(), Some(1));
    // shed requests never reached the engine: only the admitted session
    // generated (and prefilled) tokens — "hold the slot" is BOS + 13 bytes
    assert_eq!(m.get("tokens_generated").as_usize(), Some(long_tokens));
    assert_eq!(m.get("tokens_prefill").as_usize(), Some("hold the slot".len() + 1));
}

/// The chunked-prefill TTFT property, end-to-end: one long prompt plus
/// three short prompts through the real HTTP stack on a permit-gated
/// engine. The step budget we grant is strictly smaller than the long
/// prompt, so the long prefill CANNOT have finished — yet every short
/// session must reach its first output token, proven by arithmetic
/// rather than timing. This pins the bounded-TTFT invariant under
/// chunked rounds (budget accounting, rotation, admission all live);
/// the *discriminating* chunked-vs-unchunked comparison — chunking must
/// actually cut the long prompt's own TTFT — is the deterministic
/// scheduler unit test `chunked_prefill_cuts_long_prompt_ttft_rounds`.
#[test]
fn short_first_tokens_land_during_long_prefill() {
    let long_prompt = "L".repeat(64); // 65 prompt tokens with BOS
    let long_n_prompt = 64 + 1;
    let step_cap = 55u64; // < long_n_prompt: the long prefill can't finish
    let pace = Pace::new();
    let pace_engine = Arc::clone(&pace);
    let server = Server::start_with(
        ServeConfig {
            max_sessions: 8,
            queue_depth: 16,
            prefill_chunk: 2,
            round_budget_tokens: 6,
            ..ServeConfig::default()
        },
        move |_replica| paced_engine(Arc::clone(&pace_engine), 0),
    );
    let _open = Pace::open_on_drop(&pace);
    let addr = server.addr;

    let long_client = {
        let prompt = long_prompt.clone();
        std::thread::spawn(move || {
            let body = format!(r#"{{"prompt":"{prompt}","n_tokens":2,"greedy":true}}"#);
            http_post(addr, "/generate", &body).unwrap()
        })
    };
    let short_clients: Vec<_> = (0..3)
        .map(|i| {
            std::thread::spawn(move || {
                let body = format!(r#"{{"prompt":"s{i}","n_tokens":2,"greedy":true}}"#);
                http_post(addr, "/generate", &body).unwrap()
            })
        })
        .collect();
    // zero engine steps until all four are accepted — admission needs no
    // decode progress, so this arranges the mixed workload race-free
    assert!(
        wait_until(
            || fetch_metrics(addr).get("inflight_sessions").as_usize() == Some(4),
            Duration::from_secs(10)
        ),
        "mixed workload never fully admitted"
    );

    // drip steps, never exceeding the cap; the proof point is one
    // /metrics snapshot where all three shorts have produced output while
    // the long prompt is (necessarily — steps < prompt) still prefilling
    let mut granted = 0u64;
    let proven = wait_until(
        || {
            if granted < step_cap {
                pace.grant(1);
                granted += 1;
            }
            let m = fetch_metrics(addr);
            let sessions = m.get("sessions").as_arr().unwrap();
            let shorts_started = sessions
                .iter()
                .filter(|s| {
                    s.get("n_prompt").as_usize() == Some(3)
                        && s.get("generated").as_usize().unwrap_or(0) >= 1
                })
                .count();
            let long_prefilling = sessions.iter().any(|s| {
                s.get("n_prompt").as_usize() == Some(long_n_prompt)
                    && s.get("tokens").as_usize().unwrap_or(0) < long_n_prompt
            });
            // three first tokens TTFT-stamped, long prefill still pending
            shorts_started == 3
                && long_prefilling
                && m.get("ttft_ns").get("count").as_usize() == Some(3)
                && m.get("prefill_backlog").as_usize().unwrap_or(0) > 0
        },
        Duration::from_secs(30),
    );
    assert!(pace.consumed() <= step_cap, "engine outran its permit budget");
    assert!(
        proven,
        "short sessions' first tokens waited on the long prefill \
         (consumed {} steps of {step_cap})",
        pace.consumed()
    );

    // release the engine; everything completes exactly-once
    pace.open();
    for c in short_clients {
        let (status, body) = c.join().unwrap();
        assert_eq!(status, 200, "{body}");
        let v = json::parse(&body).unwrap();
        assert_eq!(v.get("n_generated").as_usize(), Some(2));
    }
    let (status, body) = long_client.join().unwrap();
    assert_eq!(status, 200, "{body}");
    let v = json::parse(&body).unwrap();
    assert_eq!(v.get("n_prompt").as_usize(), Some(long_n_prompt));
    assert_eq!(v.get("n_generated").as_usize(), Some(2));
}

/// Round-batching dedup accounting, deterministically (permit-gated
/// engine, no wall-clock margins). Phase 1: a single session's rounds
/// have one row per distinct `(layer, expert)` — `dedup_joins` stays 0
/// while `batched_rows == distinct_experts > 0`. Phase 2: three sessions
/// with IDENTICAL prompts under greedy sampling decode in lockstep, so
/// every distinct expert group carries one row from EACH session — the
/// `/metrics` deltas must show exactly one fetch plus N−1 joins per
/// group: `Δbatched_rows == 3·Δdistinct` and `Δdedup_joins == 2·Δdistinct`.
#[test]
fn round_batching_dedup_accounting_is_exact() {
    let pace = Pace::new();
    let pace_engine = Arc::clone(&pace);
    let server = Server::start_with(
        ServeConfig { max_sessions: 8, queue_depth: 16, ..ServeConfig::default() },
        move |_replica| paced_engine(Arc::clone(&pace_engine), 0),
    );
    let _open = Pace::open_on_drop(&pace);
    let addr = server.addr;

    let rb = |m: &Value, k: &str| m.get("round_batching").get(k).as_usize().unwrap();

    // --- phase 1: session A alone; its first round is one token of one
    // session, so every expert group has exactly one row
    let a_client = std::thread::spawn(move || {
        http_post(addr, "/generate", r#"{"prompt":"x","n_tokens":1,"greedy":true}"#).unwrap()
    });
    pace.grant(1); // round 1: A's BOS token, alone by construction
    assert!(
        wait_until(
            || rb(&fetch_metrics(addr), "rounds") == 1,
            Duration::from_secs(10)
        ),
        "first round never published"
    );
    let s0 = fetch_metrics(addr);
    assert_eq!(rb(&s0, "dedup_joins"), 0, "a single-session round cannot join");
    let d0 = rb(&s0, "distinct_experts");
    assert!(d0 > 0, "round executed no experts");
    assert_eq!(rb(&s0, "batched_rows"), d0, "one row per group when alone");

    // --- phase 2: three identical-prompt twins enqueue while the engine
    // is blocked inside A's second round (zero permits), so the scheduler
    // admits all three in ONE drain — they decode in lockstep from pos 0
    let twins: Vec<_> = (0..3)
        .map(|_| {
            std::thread::spawn(move || {
                http_post(addr, "/generate", r#"{"prompt":"tw","n_tokens":5,"greedy":true}"#)
                    .unwrap()
            })
        })
        .collect();
    assert!(
        wait_until(
            || fetch_metrics(addr).get("queue_depth").as_usize() == Some(3),
            Duration::from_secs(10)
        ),
        "twins were admitted before the same drain could take all three"
    );
    // round 2: A alone (1 permit); round 3: A's last token + the twins'
    // first (4 permits) — then A retires and the engine blocks again
    pace.grant(5);
    assert!(
        wait_until(
            || {
                let m = fetch_metrics(addr);
                m.get("completed_sessions").as_usize() == Some(1)
                    && rb(&m, "rounds") == 3
            },
            Duration::from_secs(10)
        ),
        "phase boundary never quiesced"
    );
    let s1 = fetch_metrics(addr);
    // lockstep precondition: all three twins advanced exactly once (in
    // round 3) — admitted together, aligned forever after
    let aligned = s1
        .get("sessions")
        .as_arr()
        .unwrap()
        .iter()
        .filter(|s| s.get("state").as_str() == Some("active"))
        .map(|s| s.get("tokens").as_usize().unwrap())
        .collect::<Vec<_>>();
    assert_eq!(aligned, vec![1, 1, 1], "twins not admitted in one drain");

    // --- release: the remaining rounds are exactly the three aligned
    // twins, so the deltas over them are exact multiples
    pace.open();
    // gate on the PUBLISHED all-done snapshot, not the live inflight
    // gauge: the gauge drops in retire(), a hair before the final round's
    // stats are published
    assert!(
        wait_until(
            || {
                let m = fetch_metrics(addr);
                m.get("sessions").as_arr().is_some_and(|ss| {
                    ss.len() == 4 && ss.iter().all(|s| s.get("state").as_str() == Some("done"))
                })
            },
            Duration::from_secs(10)
        ),
        "twins never completed"
    );
    for t in twins {
        let (status, body) = t.join().unwrap();
        assert_eq!(status, 200, "{body}");
    }
    let (status, _) = a_client.join().unwrap();
    assert_eq!(status, 200);

    let s2 = fetch_metrics(addr);
    let d_distinct = rb(&s2, "distinct_experts") - rb(&s1, "distinct_experts");
    let d_joins = rb(&s2, "dedup_joins") - rb(&s1, "dedup_joins");
    let d_rows = rb(&s2, "batched_rows") - rb(&s1, "batched_rows");
    assert!(d_distinct > 0, "twin rounds executed no experts");
    assert_eq!(d_rows, 3 * d_distinct, "each group must carry one row per twin");
    assert_eq!(d_joins, 2 * d_distinct, "each group must pay 1 fetch + N-1 joins");
    // cumulative identity and the first-arrival-pays partition
    assert_eq!(
        rb(&s2, "batched_rows") - rb(&s2, "distinct_experts"),
        rb(&s2, "dedup_joins")
    );
    let cache = s2.get("shared_cache");
    let total = cache.get("hits").as_usize().unwrap() + cache.get("misses").as_usize().unwrap();
    let part: usize = s2
        .get("sessions")
        .as_arr()
        .unwrap()
        .iter()
        .map(|s| s.get("hits").as_usize().unwrap() + s.get("misses").as_usize().unwrap())
        .sum();
    assert_eq!(part, total, "dedup joins must not break the tally partition");
}

/// Regression test for the /metrics-starvation bug: `/metrics` and
/// `/healthz` are served from a dedicated non-pooled thread, so they
/// answer within a bounded time even while every decode slot is saturated
/// by slow sessions and more work is queued. (Pre-completion-routing, each
/// in-flight /generate pinned a pool worker for its whole decode, so the
/// control endpoints queued behind blocked decodes.)
#[test]
fn control_plane_responds_during_decode_saturation() {
    let n_clients = 4usize;
    let n_tokens = 80usize;
    let server = Server::start_with(
        ServeConfig {
            http_workers: 2,
            max_sessions: 2,
            queue_depth: 8,
            ..ServeConfig::default()
        },
        |_replica| make_slow_engine(Duration::from_millis(5), 0),
    );
    let addr = server.addr;

    let clients: Vec<_> = (0..n_clients)
        .map(|i| {
            std::thread::spawn(move || {
                let body =
                    format!(r#"{{"prompt":"saturate {i}","n_tokens":{n_tokens},"greedy":true}}"#);
                http_post(addr, "/generate", &body).unwrap()
            })
        })
        .collect();

    // wait until decode is demonstrably saturated: both slots busy AND
    // work waiting in the queue
    assert!(
        wait_until(
            || {
                let m = fetch_metrics(addr);
                m.get("active_sessions").as_usize() == Some(2)
                    && m.get("queue_depth").as_usize().unwrap_or(0) >= 1
            },
            Duration::from_secs(10)
        ),
        "decode slots never saturated"
    );

    // saturated: control endpoints must still answer promptly
    assert_control_prompt(addr, "decode saturation");

    // the saturating load itself completes exactly-once
    for c in clients {
        let (status, body) = c.join().unwrap();
        assert_eq!(status, 200, "{body}");
    }
}

/// Regression test for the non-pooled control path specifically: wedge
/// EVERY HTTP worker mid-parse with a partial request (no terminating
/// blank line — the worker sits in the bounded read for seconds), then
/// require `/metrics` and `/healthz` to answer promptly anyway. Without
/// accept-time sniff routing these probes would queue behind the wedged
/// parses; with it they never touch the pool.
#[test]
fn control_plane_bypasses_wedged_http_workers() {
    let server = Server::start(
        ServeConfig { http_workers: 2, ..ServeConfig::default() },
        false,
    );
    let addr = server.addr;

    let wedgers: Vec<TcpStream> = (0..2)
        .map(|_| {
            let mut s = TcpStream::connect(addr).unwrap();
            // request line + one header, never terminated
            s.write_all(b"POST /generate HTTP/1.1\r\nHost: wedge\r\n").unwrap();
            s
        })
        .collect();
    // give both pool workers a chance to pick the wedgers up and block
    // reading — not a correctness margin: if they haven't yet, the control
    // probes below pass trivially (the regression can only FAIL when the
    // workers really are wedged, which this wait makes overwhelmingly
    // likely on any scheduler)
    std::thread::sleep(Duration::from_millis(150));

    assert_control_prompt(addr, "wedged HTTP workers");

    drop(wedgers); // workers see EOF and free up, so shutdown stays fast
}

/// `/metrics` and `/healthz` must both answer 200 within a bounded time.
fn assert_control_prompt(addr: SocketAddr, situation: &str) {
    for _ in 0..3 {
        let t0 = Instant::now();
        let (status, body) = http_get(addr, "/metrics").unwrap();
        assert_eq!(status, 200, "{body}");
        assert!(json::parse(&body).is_ok(), "{body}");
        assert!(
            t0.elapsed() < Duration::from_secs(2),
            "/metrics took {:?} under {situation}",
            t0.elapsed()
        );
        let t0 = Instant::now();
        let (status, body) = http_get(addr, "/healthz").unwrap();
        assert_eq!(status, 200);
        assert_eq!(body, "ok");
        assert!(
            t0.elapsed() < Duration::from_secs(2),
            "/healthz took {:?} under {situation}",
            t0.elapsed()
        );
    }
}

#[test]
fn invalid_requests_are_rejected_cleanly() {
    let server = Server::start(ServeConfig::default(), false);
    let (status, body) = http_post(server.addr, "/generate", r#"{"n_tokens":4}"#).unwrap();
    assert_eq!(status, 400);
    assert!(body.contains("prompt"));
    // overlong request passes parsing but fails admission
    let (status, body) = http_post(
        server.addr,
        "/generate",
        r#"{"prompt":"x","n_tokens":4000,"greedy":true}"#,
    )
    .unwrap();
    assert_eq!(status, 400, "{body}");
    assert!(body.contains("max_seq"), "{body}");
    let (status, _) = http_get(server.addr, "/nope").unwrap();
    assert_eq!(status, 404);
}

// ---------------------------------------------------------------------------
// Robustness suite: streaming, disconnect cancellation, fault ladder (§9)
// ---------------------------------------------------------------------------

/// Streamed and buffered modes must produce byte-identical completion
/// text for the same greedy request: the stable-UTF-8-prefix chunking in
/// the scheduler may only change WHERE the text is split, never the text.
#[test]
fn streamed_response_matches_buffered_text() {
    let server = Server::start(
        ServeConfig { max_sessions: 2, ..ServeConfig::default() },
        false,
    );
    let addr = server.addr;
    let body = r#"{"prompt":"stream parity","n_tokens":24,"greedy":true}"#;

    let (status, buffered) = http_post(addr, "/generate", body).unwrap();
    assert_eq!(status, 200, "{buffered}");
    let text = json::parse(&buffered)
        .unwrap()
        .get("text")
        .as_str()
        .unwrap()
        .to_string();

    let (status, chunks) = client_post_stream(addr, "/generate?stream=1", body).unwrap();
    assert_eq!(status, 200, "{chunks:?}");
    assert!(!chunks.is_empty(), "stream carried no chunks");
    assert_eq!(chunks.concat(), text, "streamed bytes must equal the buffered text");

    // a cleanly read stream is neither a disconnect nor a write error
    let m = fetch_metrics(addr);
    assert_eq!(m.get("completed_sessions").as_usize(), Some(2));
    assert_eq!(m.get("client_disconnects").as_usize(), Some(0));
    assert_eq!(m.get("write_errors").as_usize(), Some(0));
    assert_eq!(m.get("cancelled_sessions").as_usize(), Some(0));
}

/// True after the response head AND at least one complete non-empty chunk
/// have arrived — the point where the client is demonstrably mid-stream.
fn first_chunk_received(buf: &[u8]) -> bool {
    let Some(head_end) = buf.windows(4).position(|w| w == b"\r\n\r\n") else {
        return false;
    };
    let rest = &buf[head_end + 4..];
    let Some(line_end) = rest.windows(2).position(|w| w == b"\r\n") else {
        return false;
    };
    let Some(size) = std::str::from_utf8(&rest[..line_end])
        .ok()
        .and_then(|s| usize::from_str_radix(s.trim(), 16).ok())
    else {
        return false;
    };
    size > 0 && rest.len() >= line_end + 2 + size
}

/// A client that hangs up mid-stream is cancelled by the scheduler's
/// disconnect sweep: its in-flight slot is released and a concurrent
/// buffered session completes untouched, with the abandonment counted as
/// a cancellation — never as a server failure.
#[test]
fn mid_decode_disconnect_frees_resources_while_survivors_finish() {
    let doomed_tokens = 60usize;
    let survivor_tokens = 8usize;
    let server = Server::start_with(
        ServeConfig { max_sessions: 4, queue_depth: 8, ..ServeConfig::default() },
        |_replica| make_slow_engine(Duration::from_millis(2), 0),
    );
    let addr = server.addr;

    // doomed: a raw streamed connection the test can hang up mid-decode
    let mut doomed = TcpStream::connect(addr).unwrap();
    let body = format!(r#"{{"prompt":"doomed","n_tokens":{doomed_tokens},"greedy":true}}"#);
    write!(
        doomed,
        "POST /generate?stream=1 HTTP/1.1\r\nHost: t\r\nContent-Type: application/json\r\nContent-Length: {}\r\n\r\n{}",
        body.len(),
        body
    )
    .unwrap();

    let survivor = std::thread::spawn(move || {
        let body =
            format!(r#"{{"prompt":"survivor","n_tokens":{survivor_tokens},"greedy":true}}"#);
        http_post(addr, "/generate", &body).unwrap()
    });

    // read until the chunked head and a first chunk arrive: the doomed
    // session is demonstrably mid-decode (2 ms/step × 60 tokens pending)
    let mut buf = Vec::new();
    let mut tmp = [0u8; 256];
    let deadline = Instant::now() + Duration::from_secs(30);
    while !first_chunk_received(&buf) {
        assert!(Instant::now() < deadline, "no first chunk before deadline");
        let n = doomed.read(&mut tmp).unwrap();
        assert!(n > 0, "server closed the stream early");
        buf.extend_from_slice(&tmp[..n]);
    }
    let head = String::from_utf8_lossy(&buf).to_string();
    assert!(head.starts_with("HTTP/1.1 200"), "{head}");
    assert!(
        head.to_ascii_lowercase().contains("transfer-encoding: chunked"),
        "{head}"
    );

    drop(doomed); // hang up mid-stream

    // the next scheduler turn's sweep sees the dead socket and retires the
    // session at the round boundary — long before 60 tokens could finish
    assert!(
        wait_until(
            || fetch_metrics(addr).get("cancelled_sessions").as_usize() == Some(1),
            Duration::from_secs(10)
        ),
        "disconnect never cancelled the session"
    );

    let (status, body) = survivor.join().unwrap();
    assert_eq!(status, 200, "{body}");
    let v = json::parse(&body).unwrap();
    assert_eq!(v.get("n_generated").as_usize(), Some(survivor_tokens));

    assert!(
        wait_until(
            || fetch_metrics(addr).get("inflight_sessions").as_usize() == Some(0),
            Duration::from_secs(10)
        ),
        "cancelled session never released its in-flight slot"
    );
    let m = fetch_metrics(addr);
    assert_eq!(m.get("cancelled_sessions").as_usize(), Some(1));
    assert_eq!(m.get("completed_sessions").as_usize(), Some(1));
    assert_eq!(m.get("failed_sessions").as_usize(), Some(0), "a hang-up is not a failure");
    assert_eq!(m.get("active_sessions").as_usize(), Some(0));
    // the doomed decode stopped early: well under its 60-token ask
    assert!(
        m.get("tokens_generated").as_usize().unwrap() < doomed_tokens + survivor_tokens,
        "cancelled session decoded to completion anyway"
    );
    let cancelled_views = m
        .get("sessions")
        .as_arr()
        .unwrap()
        .iter()
        .filter(|s| s.get("state").as_str() == Some("cancelled"))
        .count();
    assert_eq!(cancelled_views, 1, "cancelled session missing from the ring");
}

/// Transient fetch faults under the retry budget are absorbed invisibly:
/// the request succeeds with the exact fault-free text, and the paid
/// retries surface in `/metrics` as `fetch_retries`.
#[test]
fn transient_fetch_faults_are_retried_end_to_end() {
    let body = r#"{"prompt":"retry me","n_tokens":10,"greedy":true}"#;
    // control: the fault-free text for the same greedy request
    let clean_text = {
        let control = Server::start(ServeConfig::default(), false);
        let (status, resp) = http_post(control.addr, "/generate", body).unwrap();
        assert_eq!(status, 200, "{resp}");
        json::parse(&resp).unwrap().get("text").as_str().unwrap().to_string()
    };

    // every (layer, expert) fails once before succeeding, so the test
    // does not depend on which experts the router demands
    let mc = serve_config();
    let mut plan = FaultPlan::seeded(7);
    for l in 0..mc.n_layers {
        for e in 0..mc.n_experts {
            plan = plan.fail_transient(l, e, 1);
        }
    }
    let server = Server::start_with(ServeConfig::default(), move |_replica| {
        faulty_engine(plan.clone(), 0, |c| c.fetch_retries = 2)
    });
    let (status, resp) = http_post(server.addr, "/generate", body).unwrap();
    assert_eq!(status, 200, "{resp}");
    let v = json::parse(&resp).unwrap();
    assert_eq!(v.get("n_generated").as_usize(), Some(10));
    assert_eq!(
        v.get("text").as_str(),
        Some(clean_text.as_str()),
        "retries changed timing AND tokens"
    );

    let m = fetch_metrics(server.addr);
    assert!(
        m.get("fetch_retries").as_usize().unwrap() > 0,
        "no retry surfaced in /metrics"
    );
    assert_eq!(m.get("failed_sessions").as_usize(), Some(0));
    assert_eq!(m.get("degraded_tokens").as_usize(), Some(0));
}

/// Deadline breaches degrade instead of stalling: with every expert
/// stalled far past `--demand-deadline-ms`, interactive sessions still
/// complete their full token ask — counted in `degraded_tokens` — and
/// the streamed and buffered degraded texts stay identical (the degrade
/// decision is deterministic, not a race against the wall clock).
#[test]
fn deadline_breach_degrades_interactive_sessions_to_completion() {
    let mc = serve_config();
    let mut plan = FaultPlan::seeded(3);
    for l in 0..mc.n_layers {
        for e in 0..mc.n_experts {
            plan = plan.stall_ms(l, e, 1000.0);
        }
    }
    let server = Server::start_with(ServeConfig::default(), move |_replica| {
        faulty_engine(plan.clone(), 0, |c| c.demand_deadline_ms = 1)
    });
    let addr = server.addr;
    let body = r#"{"prompt":"degrade","n_tokens":12,"greedy":true}"#;

    let (status, buffered) = http_post(addr, "/generate", body).unwrap();
    assert_eq!(status, 200, "{buffered}");
    let v = json::parse(&buffered).unwrap();
    assert_eq!(v.get("n_generated").as_usize(), Some(12), "degraded session cut short");
    let text = v.get("text").as_str().unwrap().to_string();

    let (status, chunks) = client_post_stream(addr, "/generate?stream=1", body).unwrap();
    assert_eq!(status, 200, "{chunks:?}");
    assert_eq!(chunks.concat(), text, "degraded streamed text diverged from buffered");

    let m = fetch_metrics(addr);
    assert!(
        m.get("degraded_tokens").as_usize().unwrap() > 0,
        "stalled experts never tripped the degrade path"
    );
    assert_eq!(m.get("completed_sessions").as_usize(), Some(2));
    assert_eq!(m.get("failed_sessions").as_usize(), Some(0));
    assert_eq!(m.get("cancelled_sessions").as_usize(), Some(0));
}

// ---------------------------------------------------------------------------
// Multi-replica suite: N engine workers over ONE admission queue and ONE
// shared host store (DESIGN.md §12)
// ---------------------------------------------------------------------------

/// [`round_batching_dedup_accounting_is_exact`] on a 2-replica server:
/// the same deterministic script runs pinned to replica 0 (its own
/// `Pace`), with replica 1 idle — every merged `/metrics` assertion from
/// the single-replica test must hold unchanged, because an idle replica
/// contributes zeros to the merge. Then a session pinned to replica 1
/// decodes too, and the merged dedup identity, the session-tally
/// partition, and the per-replica admission counts must all stay exact.
#[test]
fn round_batching_dedup_stays_exact_across_two_replicas() {
    let store = serve_store().unwrap();
    let pace0 = Pace::new();
    let pace1 = Pace::new();
    let paces = [Arc::clone(&pace0), Arc::clone(&pace1)];
    let server = Server::start_with(
        ServeConfig {
            engine_workers: 2,
            max_sessions: 8,
            queue_depth: 16,
            ..ServeConfig::default()
        },
        move |replica| paced_engine_with_store(Arc::clone(&paces[replica]), 0, Arc::clone(&store)),
    );
    let _open0 = Pace::open_on_drop(&pace0);
    pace1.open(); // replica 1 free-runs; it only gets work in the last phase
    let addr = server.addr;

    let rb = |m: &Value, k: &str| m.get("round_batching").get(k).as_usize().unwrap();

    // --- phase 1: session A alone on replica 0
    let a_client = std::thread::spawn(move || {
        http_post(addr, "/generate?affinity=0", r#"{"prompt":"x","n_tokens":1,"greedy":true}"#)
            .unwrap()
    });
    pace0.grant(1); // round 1: A's BOS token, alone by construction
    assert!(
        wait_until(|| rb(&fetch_metrics(addr), "rounds") == 1, Duration::from_secs(10)),
        "first round never published"
    );
    let s0 = fetch_metrics(addr);
    assert_eq!(rb(&s0, "dedup_joins"), 0, "a single-session round cannot join");
    let d0 = rb(&s0, "distinct_experts");
    assert!(d0 > 0, "round executed no experts");
    assert_eq!(rb(&s0, "batched_rows"), d0, "one row per group when alone");

    // --- phase 2: three identical twins, all pinned to replica 0, queue
    // while its engine is blocked mid-round; the IDLE replica 1 wakes on
    // every push but must leave them in place — a pinned request is
    // claimable only by its affinity target
    let twins: Vec<_> = (0..3)
        .map(|_| {
            std::thread::spawn(move || {
                http_post(
                    addr,
                    "/generate?affinity=0",
                    r#"{"prompt":"tw","n_tokens":5,"greedy":true}"#,
                )
                .unwrap()
            })
        })
        .collect();
    assert!(
        wait_until(
            || fetch_metrics(addr).get("queue_depth").as_usize() == Some(3),
            Duration::from_secs(10)
        ),
        "twins claimed early — or by the wrong replica"
    );
    // round 2: A alone (1 permit); round 3: A's last token + the twins'
    // first (4 permits) — then A retires and replica 0 blocks again
    pace0.grant(5);
    assert!(
        wait_until(
            || {
                let m = fetch_metrics(addr);
                m.get("completed_sessions").as_usize() == Some(1) && rb(&m, "rounds") == 3
            },
            Duration::from_secs(10)
        ),
        "phase boundary never quiesced"
    );
    let s1 = fetch_metrics(addr);
    let aligned = s1
        .get("sessions")
        .as_arr()
        .unwrap()
        .iter()
        .filter(|s| s.get("state").as_str() == Some("active"))
        .map(|s| s.get("tokens").as_usize().unwrap())
        .collect::<Vec<_>>();
    assert_eq!(aligned, vec![1, 1, 1], "twins not admitted in one drain");

    pace0.open();
    assert!(
        wait_until(
            || {
                let m = fetch_metrics(addr);
                m.get("sessions").as_arr().is_some_and(|ss| {
                    ss.len() == 4 && ss.iter().all(|s| s.get("state").as_str() == Some("done"))
                })
            },
            Duration::from_secs(10)
        ),
        "twins never completed"
    );
    for t in twins {
        let (status, body) = t.join().unwrap();
        assert_eq!(status, 200, "{body}");
    }
    let (status, _) = a_client.join().unwrap();
    assert_eq!(status, 200);

    let s2 = fetch_metrics(addr);
    let d_distinct = rb(&s2, "distinct_experts") - rb(&s1, "distinct_experts");
    let d_joins = rb(&s2, "dedup_joins") - rb(&s1, "dedup_joins");
    let d_rows = rb(&s2, "batched_rows") - rb(&s1, "batched_rows");
    assert!(d_distinct > 0, "twin rounds executed no experts");
    assert_eq!(d_rows, 3 * d_distinct, "each group must carry one row per twin");
    assert_eq!(d_joins, 2 * d_distinct, "each group must pay 1 fetch + N-1 joins");

    // --- phase 3: one session pinned to replica 1. Replica 0 issues odd
    // session ids (1,3,5,7 — start 1, stride 2), replica 1 even (2,4,…):
    // id spaces never collide across replicas
    let (status, body) = http_post(
        addr,
        "/generate?affinity=1",
        r#"{"prompt":"cross","n_tokens":4,"greedy":true}"#,
    )
    .unwrap();
    assert_eq!(status, 200, "{body}");
    let v = json::parse(&body).unwrap();
    assert_eq!(v.get("session_id").as_usize(), Some(2), "replica 1 strides even ids");

    let m = fetch_metrics(addr);
    assert_eq!(m.get("completed_sessions").as_usize(), Some(5));
    assert_eq!(m.get("engine_replicas_alive").as_usize(), Some(2));
    // the dedup identity survives the merge across BOTH replicas' stats
    assert_eq!(
        rb(&m, "batched_rows") - rb(&m, "distinct_experts"),
        rb(&m, "dedup_joins"),
        "dedup identity broke on the merged snapshot"
    );
    // per-session tallies across both replicas partition the merged totals
    let cache = m.get("shared_cache");
    let total = cache.get("hits").as_usize().unwrap() + cache.get("misses").as_usize().unwrap();
    let part: usize = m
        .get("sessions")
        .as_arr()
        .unwrap()
        .iter()
        .map(|s| s.get("hits").as_usize().unwrap() + s.get("misses").as_usize().unwrap())
        .sum();
    assert_eq!(part, total, "the merge must not double- or under-count tallies");
    let replicas = m.get("replicas").as_arr().unwrap();
    assert_eq!(replicas[0].get("admitted").as_usize(), Some(4));
    assert_eq!(replicas[1].get("admitted").as_usize(), Some(1));
}

/// [`mid_decode_disconnect_frees_resources_while_survivors_finish`] on a
/// 2-replica server: the doomed streamed session decodes on permit-gated
/// replica 0, the survivor on free-running replica 1. The hang-up must
/// cancel ONLY the doomed session — never its neighbor — and the
/// per-replica admission counts prove the two really were sharded.
#[test]
fn mid_decode_disconnect_on_one_replica_leaves_the_other_untouched() {
    let doomed_tokens = 60usize;
    let survivor_tokens = 8usize;
    let store = serve_store().unwrap();
    let pace0 = Pace::new();
    let pace1 = Pace::new();
    let paces = [Arc::clone(&pace0), Arc::clone(&pace1)];
    let server = Server::start_with(
        ServeConfig {
            engine_workers: 2,
            max_sessions: 4,
            queue_depth: 8,
            ..ServeConfig::default()
        },
        move |replica| paced_engine_with_store(Arc::clone(&paces[replica]), 0, Arc::clone(&store)),
    );
    let _open0 = Pace::open_on_drop(&pace0);
    pace1.open();
    let addr = server.addr;

    // doomed: a raw streamed connection pinned to replica 0
    let mut doomed = TcpStream::connect(addr).unwrap();
    let body = format!(r#"{{"prompt":"doomed","n_tokens":{doomed_tokens},"greedy":true}}"#);
    write!(
        doomed,
        "POST /generate?stream=1&affinity=0 HTTP/1.1\r\nHost: t\r\nContent-Type: application/json\r\nContent-Length: {}\r\n\r\n{}",
        body.len(),
        body
    )
    .unwrap();
    doomed.set_read_timeout(Some(Duration::from_millis(50))).unwrap();

    // survivor: pinned to free-running replica 1 — it completes no matter
    // what happens to its neighbor's session
    let survivor = std::thread::spawn(move || {
        let body =
            format!(r#"{{"prompt":"survivor","n_tokens":{survivor_tokens},"greedy":true}}"#);
        http_post(addr, "/generate?affinity=1", &body).unwrap()
    });

    // drip permits to replica 0 until the doomed stream's first chunk
    // lands (prefill + ≥ 1 decoded token), interleaving timed reads
    let mut buf = Vec::new();
    let mut tmp = [0u8; 256];
    let deadline = Instant::now() + Duration::from_secs(30);
    while !first_chunk_received(&buf) {
        assert!(Instant::now() < deadline, "no first chunk before deadline");
        pace0.grant(1);
        match doomed.read(&mut tmp) {
            Ok(n) => {
                assert!(n > 0, "server closed the stream early");
                buf.extend_from_slice(&tmp[..n]);
            }
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut => {}
            Err(e) => panic!("doomed stream read failed: {e}"),
        }
    }
    let head = String::from_utf8_lossy(&buf).to_string();
    assert!(head.starts_with("HTTP/1.1 200"), "{head}");
    drop(doomed); // hang up mid-stream

    // keep replica 0's rounds cycling so its disconnect sweep runs
    assert!(
        wait_until(
            || {
                pace0.grant(1);
                fetch_metrics(addr).get("cancelled_sessions").as_usize() == Some(1)
            },
            Duration::from_secs(10)
        ),
        "disconnect never cancelled the doomed session"
    );

    let (status, body) = survivor.join().unwrap();
    assert_eq!(status, 200, "{body}");
    let v = json::parse(&body).unwrap();
    assert_eq!(v.get("n_generated").as_usize(), Some(survivor_tokens));
    assert_eq!(v.get("session_id").as_usize(), Some(2), "survivor decoded on replica 1");

    assert!(
        wait_until(
            || fetch_metrics(addr).get("inflight_sessions").as_usize() == Some(0),
            Duration::from_secs(10)
        ),
        "cancelled session never released its in-flight slot"
    );
    let m = fetch_metrics(addr);
    assert_eq!(m.get("cancelled_sessions").as_usize(), Some(1));
    assert_eq!(m.get("completed_sessions").as_usize(), Some(1));
    assert_eq!(m.get("failed_sessions").as_usize(), Some(0));
    assert_eq!(
        m.get("engine_replicas_alive").as_usize(),
        Some(2),
        "a client hang-up is not a replica death"
    );
    let replicas = m.get("replicas").as_arr().unwrap();
    assert_eq!(replicas[0].get("admitted").as_usize(), Some(1));
    assert_eq!(replicas[1].get("admitted").as_usize(), Some(1));
    assert_eq!(replicas[1].get("completed_sessions").as_usize(), Some(1));
}

/// Kill replica 0 mid-stream (injected backend panic) and prove the blast
/// radius is exactly one replica: its in-flight session is 500'd (stream
/// cut unterminated), `engine_replicas_alive` drops to 1, the admission
/// queue STAYS open, a survivor mid-decode on replica 1 finishes with
/// text bit-identical to a single-replica control run, and affinity keys
/// that pinned to the dead replica remap onto the alive set.
#[test]
fn replica_death_quarantines_itself_and_survivors_finish_bit_identical() {
    let survivor_body = r#"{"prompt":"survivor","n_tokens":12,"greedy":true}"#;
    // control: the same greedy request on a plain single-replica server
    let control_text = {
        let control = Server::start(ServeConfig::default(), false);
        let (status, resp) = http_post(control.addr, "/generate", survivor_body).unwrap();
        assert_eq!(status, 200, "{resp}");
        json::parse(&resp).unwrap().get("text").as_str().unwrap().to_string()
    };

    let store = serve_store().unwrap();
    let pace0 = Pace::new();
    let pace1 = Pace::new();
    let kill = KillSwitch::new();
    let (mk_pace0, mk_pace1, mk_kill, mk_store) =
        (Arc::clone(&pace0), Arc::clone(&pace1), kill.clone(), Arc::clone(&store));
    let server = Server::start_with(
        ServeConfig {
            engine_workers: 2,
            max_sessions: 4,
            queue_depth: 8,
            ..ServeConfig::default()
        },
        move |replica| {
            if replica == 0 {
                killable_paced_engine(
                    Arc::clone(&mk_pace0),
                    0,
                    Arc::clone(&mk_store),
                    mk_kill.clone(),
                )
            } else {
                paced_engine_with_store(Arc::clone(&mk_pace1), 0, Arc::clone(&mk_store))
            }
        },
    );
    let _open0 = Pace::open_on_drop(&pace0);
    let _open1 = Pace::open_on_drop(&pace1);
    let addr = server.addr;

    // victim: streamed, pinned to replica 0, held mid-decode by its pace
    let mut victim = TcpStream::connect(addr).unwrap();
    let body = r#"{"prompt":"victim","n_tokens":40,"greedy":true}"#;
    write!(
        victim,
        "POST /generate?stream=1&affinity=0 HTTP/1.1\r\nHost: t\r\nContent-Type: application/json\r\nContent-Length: {}\r\n\r\n{}",
        body.len(),
        body
    )
    .unwrap();
    victim.set_read_timeout(Some(Duration::from_millis(50))).unwrap();
    let mut buf = Vec::new();
    let mut tmp = [0u8; 256];
    let deadline = Instant::now() + Duration::from_secs(30);
    while !first_chunk_received(&buf) {
        assert!(Instant::now() < deadline, "victim never reached mid-stream");
        pace0.grant(1);
        match victim.read(&mut tmp) {
            Ok(n) => {
                assert!(n > 0, "server closed the victim stream before the kill");
                buf.extend_from_slice(&tmp[..n]);
            }
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut => {}
            Err(e) => panic!("victim read failed: {e}"),
        }
    }

    // survivor: admitted on replica 1 BEFORE the kill, held mid-decode by
    // ITS pace — it must ride out its neighbor's death untouched
    let survivor = std::thread::spawn(move || {
        http_post(addr, "/generate?affinity=1", survivor_body).unwrap()
    });
    assert!(
        wait_until(
            || {
                let m = fetch_metrics(addr);
                m.get("queue_depth").as_usize() == Some(0)
                    && m.get("inflight_sessions").as_usize() == Some(2)
            },
            Duration::from_secs(10)
        ),
        "survivor never claimed by replica 1"
    );

    // kill: the next granted step on replica 0 panics its scheduler; the
    // WorkerGuard must quarantine exactly that replica
    kill.kill();
    assert!(
        wait_until(
            || {
                pace0.grant(1);
                fetch_metrics(addr).get("engine_replicas_alive").as_usize() == Some(1)
            },
            Duration::from_secs(10)
        ),
        "replica 0's death never quarantined it"
    );

    // one dead replica must NOT mark the server down
    let (status, hbody) = http_get(addr, "/healthz").unwrap();
    assert_eq!(status, 200);
    assert_eq!(hbody, "ok", "one dead replica must not fail /healthz");

    // the victim's stream is cut without the chunked terminator
    let dead_deadline = Instant::now() + Duration::from_secs(10);
    loop {
        assert!(Instant::now() < dead_deadline, "victim stream never terminated");
        match victim.read(&mut tmp) {
            Ok(0) => break,
            Ok(n) => buf.extend_from_slice(&tmp[..n]),
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut => {}
            Err(_) => break,
        }
    }
    let tail = String::from_utf8_lossy(&buf);
    assert!(
        !tail.ends_with("0\r\n\r\n"),
        "a killed stream must not terminate cleanly: {tail}"
    );

    // the survivor rides out the death bit-identically
    pace1.open();
    let (status, sbody) = survivor.join().unwrap();
    assert_eq!(status, 200, "{sbody}");
    let v = json::parse(&sbody).unwrap();
    assert_eq!(v.get("n_generated").as_usize(), Some(12));
    assert_eq!(v.get("session_id").as_usize(), Some(2), "survivor decoded on replica 1");
    assert_eq!(
        v.get("text").as_str(),
        Some(control_text.as_str()),
        "replica death changed a survivor's tokens"
    );

    // affinity keys remap over the alive set: a key that pinned to the
    // dead replica 0 now lands on replica 1 — the queue is still open and
    // the result is still bit-identical
    let (status, rbody) = http_post(addr, "/generate?affinity=0", survivor_body).unwrap();
    assert_eq!(status, 200, "queue must stay open after a replica death: {rbody}");
    let v = json::parse(&rbody).unwrap();
    assert_eq!(v.get("text").as_str(), Some(control_text.as_str()));
    assert_eq!(
        v.get("session_id").as_usize(),
        Some(4),
        "remapped session must decode on replica 1"
    );

    let m = fetch_metrics(addr);
    assert_eq!(m.get("engine_replicas_alive").as_usize(), Some(1));
    assert_eq!(
        m.get("failed_sessions").as_usize(),
        Some(1),
        "the victim is a failure, not a completion"
    );
    assert_eq!(m.get("completed_sessions").as_usize(), Some(2));
    assert!(m.get("errors").as_usize().unwrap() >= 1, "the victim's 500 went uncounted");
    let replicas = m.get("replicas").as_arr().unwrap();
    assert_eq!(replicas[0].get("alive").as_bool(), Some(false));
    assert_eq!(replicas[1].get("alive").as_bool(), Some(true));
    assert_eq!(replicas[1].get("completed_sessions").as_usize(), Some(2));
}
