//! Property-based tests (via the in-repo quickcheck substrate) on the
//! system's core invariants.

use moe_offload::cache::{LayerCache, PolicyKind};
use moe_offload::engine::{EngineConfig, EngineReplica, InferenceEngine};
use moe_offload::metrics::{PrecisionRecall, RoundBatchStats, ServeMetrics};
use moe_offload::model::sampler::{top_k, Sampler, Sampling};
use moe_offload::model::weights::generate_weights;
use moe_offload::model::ModelConfig;
use moe_offload::offload::learned::{self, LearnedPredictor, TrainConfig};
use moe_offload::offload::prefetch::PrefetchSource;
use moe_offload::offload::store::{HostExpertStore, HostTierConfig};
use moe_offload::quant::{QTensor, Scheme};
use moe_offload::runtime::native::NativeBackend;
use moe_offload::serve::scheduler::{
    run_replica, RoundReport, Scheduler, SchedulerConfig, ServeSnapshot,
};
use moe_offload::serve::{
    AdmissionQueue, GenRequest, GenResult, Priority, ReplicaRouter, ReplyTo,
};
use moe_offload::sim::{cachesim, tracegen};
use moe_offload::util::json::{self, Value};
use moe_offload::util::quickcheck::{forall, Gen};
use std::sync::atomic::Ordering;
use std::sync::mpsc::{channel, Receiver};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

#[test]
fn prop_cache_capacity_never_exceeded() {
    forall(150, |g: &mut Gen| {
        let cap = g.usize(1..=8);
        let kind = *g.choose(&PolicyKind::all_online());
        let seed = g.usize(0..=1000) as u64;
        let mut cache: LayerCache<usize> = LayerCache::new(cap, kind.build(seed, None));
        let accesses = g.vec_usize(1..=300, 0..=15);
        for e in accesses {
            if cache.access(e).is_none() {
                cache.insert(e, e);
            }
            if cache.len() > cap {
                return Err(format!("{}: {} residents > cap {cap}", kind.name(), cache.len()));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_cache_residency_matches_contains() {
    forall(100, |g: &mut Gen| {
        let cap = g.usize(1..=6);
        let kind = *g.choose(&PolicyKind::all_online());
        let mut cache: LayerCache<()> = LayerCache::new(cap, kind.build(1, None));
        for e in g.vec_usize(1..=200, 0..=9) {
            if cache.access(e).is_none() {
                cache.insert(e, ());
            }
            // the just-accessed expert must be resident
            if !cache.contains(e) {
                return Err(format!("{e} not resident right after access"));
            }
            let resident = cache.resident();
            if resident.len() != resident.iter().collect::<std::collections::HashSet<_>>().len() {
                return Err("duplicate residents".into());
            }
        }
        Ok(())
    });
}

#[test]
fn prop_lru_hit_rate_monotone_in_capacity() {
    // LRU is a stack algorithm: inclusion property => monotone hit rate
    forall(40, |g: &mut Gen| {
        let tokens = g.usize(20..=120);
        let seed = g.usize(0..=10_000) as u64;
        let trace = tracegen::generate(&tracegen::TraceGenConfig {
            n_layers: 4,
            n_tokens: tokens.max(20),
            seed,
            ..Default::default()
        });
        let mut prev = -1.0f64;
        for cap in 1..=8 {
            let r = cachesim::compare(&trace, &[PolicyKind::Lru], cap, 0);
            let hr = r[0].stats.hit_rate();
            if hr < prev - 1e-9 {
                return Err(format!("cap {cap}: hit rate {hr} < {prev}"));
            }
            prev = hr;
        }
        Ok(())
    });
}

#[test]
fn prop_belady_dominates_all_online_policies() {
    forall(30, |g: &mut Gen| {
        let seed = g.usize(0..=10_000) as u64;
        let cap = g.usize(2..=6);
        let trace = tracegen::generate(&tracegen::TraceGenConfig {
            n_layers: 3,
            n_tokens: 80,
            seed,
            locality: g.f64(0.0..=0.8),
            ..Default::default()
        });
        let results = cachesim::compare(
            &trace,
            &[PolicyKind::Belady, PolicyKind::Lru, PolicyKind::Lfu, PolicyKind::Fifo],
            cap,
            seed,
        );
        let b = results[0].stats.hit_rate();
        for r in &results[1..] {
            if r.stats.hit_rate() > b + 1e-9 {
                return Err(format!(
                    "{:?} ({}) beat belady ({b}) at cap {cap} seed {seed}",
                    r.policy,
                    r.stats.hit_rate()
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_pipeline_decode_bit_identical_to_sync() {
    // cache transparency must survive concurrency: across policies
    // (including learned eviction) × quantization schemes × prefetch
    // sources × prefetch on/off, the async transfer pipeline (any worker
    // count) produces bit-identical decodes to the synchronous fetch
    // path — same tokens, same per-token logits.
    forall(10, |g: &mut Gen| {
        let seed = g.usize(0..=999) as u64;
        let scheme = *g.choose(&[
            Scheme::F32,
            Scheme::Int8 { block: 16 },
            Scheme::Int4 { block: 16 },
        ]);
        let mut policies = PolicyKind::all_online().to_vec();
        policies.push(PolicyKind::Learned);
        let policy = *g.choose(&policies);
        let source = *g.choose(&PrefetchSource::ALL);
        let prefetch = g.bool();
        let capacity = g.usize(2..=6);
        // a predictor trained on a small synthetic trace with the TINY
        // model's geometry, exercised by the learned policy/source paths
        let predictor = (policy == PolicyKind::Learned || source == PrefetchSource::Learned)
            .then(|| {
                let trace = tracegen::generate(&tracegen::TraceGenConfig {
                    n_layers: ModelConfig::TINY.n_layers,
                    n_tokens: 64,
                    seed,
                    ..Default::default()
                });
                let cfg = TrainConfig { epochs: 2, lr: 0.1 };
                learned::train_on_trace(&trace, &cfg).unwrap().predictor
            });
        let run = |workers: usize| {
            let weights = Arc::new(generate_weights(ModelConfig::TINY, seed));
            let store = Arc::new(HostExpertStore::build(&weights, scheme).unwrap());
            let mut cfg = EngineConfig::serving(capacity, policy, prefetch);
            cfg.seed = seed;
            cfg.transfer_workers = workers;
            cfg.prefetch_source = source;
            let mut engine = InferenceEngine::with_predictor(
                Box::new(NativeBackend::new(weights)),
                store,
                cfg,
                predictor.clone(),
            );
            let mut sampler = Sampler::new(Sampling::Greedy, seed);
            let out = engine.generate(&[1, 5, 9], 7, &mut sampler).unwrap();
            // decode outputs + the exact logits of one extra step
            let mut kv = moe_offload::runtime::KvState::zeros(engine.config());
            let mut ev = moe_offload::sim::costmodel::TokenEvents::default();
            let logits = engine.step(out.tokens[0], &mut kv, 0, &mut ev).unwrap();
            (out.tokens, logits)
        };
        let (sync_tokens, sync_logits) = run(0);
        for workers in [1usize, 3] {
            let (tokens, logits) = run(workers);
            if tokens != sync_tokens {
                return Err(format!(
                    "{}/{}/{}/prefetch={prefetch}/cap={capacity}/workers={workers}: \
                     tokens diverged from sync path",
                    policy.name(),
                    scheme.name(),
                    source.name()
                ));
            }
            if logits != sync_logits {
                return Err(format!(
                    "{}/{}/{}/workers={workers}: logits not bit-identical",
                    policy.name(),
                    scheme.name(),
                    source.name()
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_predictor_decode_bit_identical_and_deterministic() {
    // the learned predictor only warms the cache and ranks victims — it
    // must never change what the model computes. Decodes with learned
    // prefetch + learned eviction are bit-identical to a predictor-free
    // LRU baseline, and two identical learned runs agree exactly (tokens,
    // logits, cache counters, predictor precision/recall).
    forall(8, |g: &mut Gen| {
        let seed = g.usize(0..=999) as u64;
        let scheme = *g.choose(&[Scheme::F32, Scheme::Int8 { block: 16 }]);
        let capacity = g.usize(2..=6);
        let workers = *g.choose(&[0usize, 2]);
        let source = *g.choose(&[PrefetchSource::Markov, PrefetchSource::Learned]);
        let trace = tracegen::generate(&tracegen::TraceGenConfig {
            n_layers: ModelConfig::TINY.n_layers,
            n_tokens: 96,
            seed,
            ..Default::default()
        });
        let cfg = TrainConfig { epochs: 2, lr: 0.1 };
        let predictor = learned::train_on_trace(&trace, &cfg).unwrap().predictor;
        let run = |policy: PolicyKind, src: PrefetchSource, pred: Option<LearnedPredictor>| {
            let weights = Arc::new(generate_weights(ModelConfig::TINY, seed));
            let store = Arc::new(HostExpertStore::build(&weights, scheme).unwrap());
            let mut cfg = EngineConfig::serving(capacity, policy, true);
            cfg.seed = seed;
            cfg.transfer_workers = workers;
            cfg.prefetch_source = src;
            let mut engine = InferenceEngine::with_predictor(
                Box::new(NativeBackend::new(weights)),
                store,
                cfg,
                pred,
            );
            let mut sampler = Sampler::new(Sampling::Greedy, seed);
            let out = engine.generate(&[2, 7], 6, &mut sampler).unwrap();
            let mut kv = moe_offload::runtime::KvState::zeros(engine.config());
            let mut ev = moe_offload::sim::costmodel::TokenEvents::default();
            let logits = engine.step(out.tokens[0], &mut kv, 0, &mut ev).unwrap();
            let stats = engine.cache_stats();
            let pr = engine.predictor_precision_recall();
            (out.tokens, logits, (stats.hits, stats.misses, stats.evictions), pr)
        };
        let (base_tokens, base_logits, _, _) = run(PolicyKind::Lru, PrefetchSource::Gate, None);
        let (tokens, logits, counters, pr) =
            run(PolicyKind::Learned, source, Some(predictor.clone()));
        if tokens != base_tokens {
            return Err(format!(
                "{}/{}/cap={capacity}/workers={workers}: learned run changed tokens",
                scheme.name(),
                source.name()
            ));
        }
        if logits != base_logits {
            return Err(format!(
                "{}/{}: learned run changed logits",
                scheme.name(),
                source.name()
            ));
        }
        let (tokens2, logits2, counters2, pr2) =
            run(PolicyKind::Learned, source, Some(predictor.clone()));
        if tokens2 != tokens || logits2 != logits || counters2 != counters {
            return Err(format!(
                "{}/{}: learned run is not deterministic (counters {counters:?} vs {counters2:?})",
                scheme.name(),
                source.name()
            ));
        }
        if (pr2.tp, pr2.fp, pr2.fn_) != (pr.tp, pr.fp, pr.fn_) {
            return Err(format!(
                "{}/{}: predictor precision/recall not deterministic",
                scheme.name(),
                source.name()
            ));
        }
        Ok(())
    });
}

#[test]
fn prop_tiered_store_bit_identical_to_all_ram() {
    // the disk tier under host RAM moves bytes, it never rewrites them:
    // across GPU-cache policies × host-tier policies × quantization schemes
    // × prefetch on/off × worker counts × pathologically small RAM budgets
    // (down to a single resident entry for 16 experts), a tiered store must
    // decode bit-identically to the all-RAM store, and its counters must
    // conserve accesses (ram_hits + disk_promotions == host_accesses).
    forall(8, |g: &mut Gen| {
        let seed = g.usize(0..=999) as u64;
        let scheme = *g.choose(&[
            Scheme::F32,
            Scheme::Int8 { block: 16 },
            Scheme::Int4 { block: 16 },
        ]);
        let policy = *g.choose(&PolicyKind::all_online());
        let host_policy = *g.choose(&PolicyKind::all_online());
        let prefetch = g.bool();
        let capacity = g.usize(2..=6);
        let workers = *g.choose(&[0usize, 2]);
        let budget_entries = g.usize(1..=4);

        let run = |budget: Option<usize>| {
            let weights = Arc::new(generate_weights(ModelConfig::TINY, seed));
            let store = match budget {
                Some(entries) => {
                    let entry_bytes = HostExpertStore::build(&weights, scheme)
                        .unwrap()
                        .expert_transfer_bytes();
                    let tier = HostTierConfig {
                        ram_budget_bytes: entries * entry_bytes,
                        policy: host_policy,
                        seed,
                        spill_dir: None,
                    };
                    Arc::new(HostExpertStore::build_tiered(&weights, scheme, &tier).unwrap())
                }
                None => Arc::new(HostExpertStore::build(&weights, scheme).unwrap()),
            };
            let mut cfg = EngineConfig::serving(capacity, policy, prefetch);
            cfg.seed = seed;
            cfg.transfer_workers = workers;
            let mut engine = InferenceEngine::new(
                Box::new(NativeBackend::new(weights)),
                Arc::clone(&store),
                cfg,
            );
            let mut sampler = Sampler::new(Sampling::Greedy, seed);
            let out = engine.generate(&[1, 5, 9], 7, &mut sampler).unwrap();
            (out.tokens, store.tier_stats())
        };

        let (ram_tokens, _) = run(None);
        let (tokens, ht) = run(Some(budget_entries));
        if tokens != ram_tokens {
            return Err(format!(
                "{}/{}/host={}/prefetch={prefetch}/cap={capacity}/workers={workers}/\
                 budget={budget_entries}: tiered decode diverged from all-RAM",
                policy.name(),
                scheme.name(),
                host_policy.name()
            ));
        }
        if ht.host_accesses == 0 {
            return Err("tiered run never touched the host tier".into());
        }
        if ht.ram_hits + ht.disk_promotions != ht.host_accesses {
            return Err(format!(
                "tier counters leak: {} hits + {} promotions != {} accesses",
                ht.ram_hits, ht.disk_promotions, ht.host_accesses
            ));
        }
        Ok(())
    });
}

#[test]
fn prop_serve_admission_exactly_once() {
    // serve-layer admission invariants, across random (engine replicas,
    // transfer workers, session cap, queue depth, request bursts):
    //   * every accepted request gets EXACTLY one answer;
    //   * a rejected request is never also served;
    //   * answers match their request (distinct n_tokens per request — a
    //     cross-session payload swap would be visible immediately);
    //   * stale requests are shed with 503 and consume zero engine steps
    //     (summed total_steps() equals the steps of served sessions only);
    //   * with N ∈ {1, 2, 4} replicas racing to claim from the ONE queue,
    //     each request — pinned by affinity or not — is still answered
    //     exactly once: claim-or-shed is atomic under the queue lock.
    forall(6, |g: &mut Gen| {
        let n_replicas = *g.choose(&[1usize, 2, 4]);
        let transfer_workers = *g.choose(&[0usize, 1, 3]);
        let max_sessions = g.usize(1..=4);
        let depth = g.usize(1..=6);
        let n_bursts = g.usize(1..=3);
        // fresh requests can never age past this within one test run;
        // stale ones are backdated far beyond it (skipped if the machine
        // hasn't been up long enough to backdate)
        let timeout = Duration::from_secs(60);
        let backdate = Instant::now().checked_sub(Duration::from_secs(300));

        let metrics = Arc::new(ServeMetrics::default());
        let queue = AdmissionQueue::new(depth, Arc::clone(&metrics));
        let router = ReplicaRouter::new(n_replicas);
        let (completions, _completion_rx) = channel();

        // the engines are not Send: each replica builds its own on its
        // scheduler thread; all N race to claim from the one queue
        let schedulers: Vec<_> = (0..n_replicas)
            .map(|r| {
                let sched_queue = Arc::clone(&queue);
                let sched_metrics = Arc::clone(&metrics);
                let sched_router = Arc::clone(&router);
                let sched_completions = completions.clone();
                let snapshot = Arc::new(Mutex::new(ServeSnapshot::default()));
                std::thread::spawn(move || {
                    let cfg_model =
                        ModelConfig { vocab_size: 320, max_seq: 96, ..ModelConfig::TINY };
                    let weights = Arc::new(generate_weights(cfg_model, 7));
                    let store =
                        Arc::new(HostExpertStore::build(&weights, Scheme::F32).unwrap());
                    let mut cfg = EngineConfig::serving(4, PolicyKind::Lfu, false);
                    cfg.transfer_workers = transfer_workers;
                    let engine =
                        InferenceEngine::new(Box::new(NativeBackend::new(weights)), store, cfg);
                    let engine = run_replica(
                        EngineReplica::new(r, engine),
                        sched_queue,
                        sched_completions,
                        SchedulerConfig {
                            max_sessions,
                            queue_timeout: Some(timeout),
                            ..SchedulerConfig::default()
                        },
                        sched_metrics,
                        snapshot,
                        sched_router,
                    );
                    engine.total_steps()
                })
            })
            .collect();
        drop(completions);

        let mut accepted: Vec<(usize, Receiver<GenResult>, bool)> = Vec::new();
        let mut rejected: Vec<(usize, Receiver<GenResult>)> = Vec::new();
        let mut idx = 0usize;
        for _ in 0..n_bursts {
            for _ in 0..g.usize(1..=8) {
                let i = idx;
                idx += 1;
                let (tx, rx) = channel();
                let (enqueued, stale) = match (g.bool(), backdate) {
                    (true, Some(t)) => (t, true),
                    _ => (Instant::now(), false),
                };
                let req = GenRequest {
                    prompt: format!("req {i}"),
                    n_tokens: 1 + (i % 12),
                    sampling: Sampling::Greedy,
                    priority: Priority::Interactive,
                    reply: ReplyTo::Channel(tx),
                    // a random subset is affinity-pinned: pinned requests
                    // are claimable by exactly one replica, which must
                    // not break exactly-once (nor strand them)
                    affinity: g.bool().then_some((i % 5) as u64),
                    enqueued,
                };
                match queue.try_push(req) {
                    Ok(()) => accepted.push((i, rx, stale)),
                    // the request (and its reply sender) is handed back
                    // and dropped here: a rejected request has no path to
                    // a response
                    Err(_refused) => rejected.push((i, rx)),
                }
            }
            std::thread::sleep(Duration::from_millis(g.usize(0..=2) as u64));
        }
        queue.close();
        let total_steps: u64 = schedulers
            .into_iter()
            .map(|s| s.join().expect("scheduler thread"))
            .sum();

        let mut served_steps = 0u64;
        let mut shed_count = 0u64;
        for (i, rx, stale) in &accepted {
            let first = rx
                .recv()
                .map_err(|_| format!("request {i} accepted but never answered"))?;
            match first {
                Ok(resp) => {
                    if *stale {
                        return Err(format!("stale request {i} was decoded, not shed"));
                    }
                    if resp.n_generated != 1 + (i % 12) {
                        return Err(format!(
                            "request {i}: n_generated {} — cross-request payload swap",
                            resp.n_generated
                        ));
                    }
                    // byte tokenizer: BOS + one token per prompt byte
                    if resp.n_prompt != format!("req {i}").len() + 1 {
                        return Err(format!("request {i}: wrong prompt length {}", resp.n_prompt));
                    }
                    served_steps += (resp.n_prompt + resp.n_generated) as u64;
                }
                Err(ge) => {
                    if !*stale {
                        return Err(format!("fresh request {i} refused: {}", ge.message));
                    }
                    if ge.status != 503 || ge.retry_after.is_none() {
                        return Err(format!(
                            "shed must be 503 + Retry-After, got {} / {:?}",
                            ge.status, ge.retry_after
                        ));
                    }
                    shed_count += 1;
                }
            }
            if rx.try_recv().is_ok() {
                return Err(format!("request {i} answered more than once"));
            }
        }
        for (i, rx) in &rejected {
            if rx.recv().is_ok() {
                return Err(format!("request {i} was both rejected and served"));
            }
        }
        if total_steps != served_steps {
            return Err(format!(
                "engine stepped {total_steps} tokens but served sessions account for \
                 {served_steps} — shed/rejected requests consumed engine work"
            ));
        }
        if metrics.shed_total.load(Ordering::Relaxed) != shed_count {
            return Err(format!(
                "shed_total {} != shed responses {shed_count}",
                metrics.shed_total.load(Ordering::Relaxed)
            ));
        }
        Ok(())
    });
}

#[test]
fn prop_chunked_prefill_fair_and_bit_identical() {
    // chunked-prefill/continuous-batching invariants, across random mixes
    // of prompt lengths, generation lengths, chunk sizes, round budgets
    // and session caps:
    //   * every admitted session eventually completes (the turn loop ends
    //     and every receiver holds an Ok with the right n_generated);
    //   * no round advances more tokens than the configured budget, at
    //     most one prefill chunk (≤ chunk tokens) per round, decode steps
    //     are one token each;
    //   * no starvation: a candidate skipped for budget advances within
    //     the next `max_sessions + 1` rounds (deficit carry-over);
    //   * outputs are bit-identical to `prefill_chunk = 0` — chunking is
    //     scheduling, not semantics.
    forall(6, |g: &mut Gen| {
        let n_req = g.usize(2..=6);
        let chunk = g.usize(1..=6);
        let budget = *g.choose(&[0usize, 1, 2, 3, 6, 10]);
        let max_sessions = g.usize(2..=4);
        let requests: Vec<(String, usize)> = (0..n_req)
            .map(|i| {
                let prompt =
                    String::from_utf8(vec![b'a' + (i as u8 % 26); g.usize(1..=40)]).unwrap();
                (prompt, g.usize(1..=6))
            })
            .collect();
        let sampling = if g.bool() {
            Sampling::Greedy
        } else {
            Sampling::TopP { temperature: 0.9, top_p: 0.9 }
        };

        let run = |chunk: usize,
                   budget: usize|
         -> Result<(Vec<String>, Vec<RoundReport>), String> {
            let cfg_model = ModelConfig { vocab_size: 320, max_seq: 96, ..ModelConfig::TINY };
            let weights = Arc::new(generate_weights(cfg_model, 7));
            let store = Arc::new(HostExpertStore::build(&weights, Scheme::F32).unwrap());
            let engine = InferenceEngine::new(
                Box::new(NativeBackend::new(weights)),
                store,
                EngineConfig::serving(4, PolicyKind::Lfu, true),
            );
            let metrics = Arc::new(ServeMetrics::default());
            let queue = AdmissionQueue::new(n_req, Arc::clone(&metrics));
            let (completions, _completion_rx) = channel();
            let mut rxs: Vec<Receiver<GenResult>> = Vec::new();
            for (prompt, n_tokens) in &requests {
                let (tx, rx) = channel();
                queue
                    .try_push(GenRequest {
                        prompt: prompt.clone(),
                        n_tokens: *n_tokens,
                        sampling,
                        priority: Priority::Interactive,
                        reply: ReplyTo::Channel(tx),
                        affinity: None,
                        enqueued: Instant::now(),
                    })
                    .ok()
                    .ok_or("queue sized for the burst")?;
                rxs.push(rx);
            }
            queue.close();
            let mut sched = Scheduler::new(
                engine,
                queue,
                completions,
                SchedulerConfig {
                    max_sessions,
                    queue_timeout: None,
                    prefill_chunk: chunk,
                    round_budget_tokens: budget,
                    round_batching: true,
                },
                metrics,
                Arc::new(Mutex::new(ServeSnapshot::default())),
            );
            let mut reports = Vec::new();
            while let Some(r) = sched.turn() {
                reports.push(r);
                if reports.len() > 100_000 {
                    return Err("scheduler failed to terminate (liveness)".into());
                }
            }
            let mut texts = Vec::new();
            for (i, rx) in rxs.iter().enumerate() {
                let resp = rx
                    .recv()
                    .map_err(|_| format!("request {i} never answered"))?
                    .map_err(|e| format!("request {i} failed: {}", e.message))?;
                if resp.n_generated != requests[i].1 {
                    return Err(format!(
                        "request {i}: n_generated {} != {}",
                        resp.n_generated, requests[i].1
                    ));
                }
                texts.push(resp.text);
            }
            Ok((texts, reports))
        };

        let (base_texts, _) = run(0, 0)?;
        let (texts, reports) = run(chunk, budget)?;
        if texts != base_texts {
            return Err(format!(
                "outputs diverged from the unchunked path (chunk {chunk}, budget {budget})"
            ));
        }

        let mut starving: std::collections::HashMap<u64, usize> = Default::default();
        for r in &reports {
            let total = r.decode_tokens + r.prefill_tokens;
            if budget > 0 && total > budget {
                return Err(format!(
                    "round {} advanced {total} tokens over budget {budget}",
                    r.round
                ));
            }
            let prefill_chunks = r.advanced.iter().filter(|a| a.prefill).count();
            if prefill_chunks > 1 {
                return Err(format!("round {}: {prefill_chunks} prefill chunks", r.round));
            }
            for a in &r.advanced {
                if a.prefill && a.tokens > chunk {
                    return Err(format!(
                        "round {}: chunk of {} > prefill_chunk {chunk}",
                        r.round, a.tokens
                    ));
                }
                if !a.prefill && a.tokens != 1 {
                    return Err(format!(
                        "round {}: decode step of {} tokens",
                        r.round, a.tokens
                    ));
                }
                starving.remove(&a.session);
            }
            // deficit carry-over: skipped candidates must advance within
            // max_sessions + 1 rounds (candidates ≤ sessions + the one
            // prefill unit, and ≥ 1 candidate is served per round)
            for &id in &r.skipped {
                let c = starving.entry(id).or_insert(0);
                *c += 1;
                if *c > max_sessions + 1 {
                    return Err(format!(
                        "session {id} skipped {c} consecutive rounds (round {}): starvation",
                        r.round
                    ));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_round_batching_bit_identical() {
    // round-level expert batching is scheduling + dedup, not semantics:
    // across random session counts × prompts × cache policies × quant
    // schemes × prefetch on/off × chunk/budget settings, every session's
    // full token stream under round batching (one `step_round` dispatch
    // per round, per-(layer, expert) dedup) is bit-identical to the
    // legacy per-session path (`--round-batching off`). The dedup ledger
    // must also stay structurally exact on the batched run —
    // `batched_rows − distinct_experts == dedup_joins` — while the legacy
    // run records zero batched activity.
    forall(6, |g: &mut Gen| {
        let n_req = g.usize(2..=5);
        let policy = *g.choose(&PolicyKind::all_online());
        let scheme = *g.choose(&[Scheme::F32, Scheme::Int8 { block: 16 }]);
        let prefetch = g.bool();
        let capacity = g.usize(2..=6);
        let chunk = *g.choose(&[0usize, 2, 5]);
        let budget = *g.choose(&[0usize, 3, 8]);
        let max_sessions = g.usize(2..=4);
        // a two-letter alphabet makes duplicate prompts (the interesting
        // dedup case) common without forcing them
        let requests: Vec<(String, usize)> = (0..n_req)
            .map(|i| {
                let prompt =
                    String::from_utf8(vec![b'a' + (i as u8 % 2); g.usize(1..=24)]).unwrap();
                (prompt, g.usize(1..=6))
            })
            .collect();
        let sampling = if g.bool() {
            Sampling::Greedy
        } else {
            Sampling::TopP { temperature: 0.9, top_p: 0.9 }
        };

        let run = |round_batching: bool| -> Result<(Vec<String>, RoundBatchStats), String> {
            let cfg_model = ModelConfig { vocab_size: 320, max_seq: 96, ..ModelConfig::TINY };
            let weights = Arc::new(generate_weights(cfg_model, 7));
            let store = Arc::new(HostExpertStore::build(&weights, scheme).unwrap());
            let engine = InferenceEngine::new(
                Box::new(NativeBackend::new(weights)),
                store,
                EngineConfig::serving(capacity, policy, prefetch),
            );
            let metrics = Arc::new(ServeMetrics::default());
            let queue = AdmissionQueue::new(n_req, Arc::clone(&metrics));
            let (completions, _completion_rx) = channel();
            let mut rxs: Vec<Receiver<GenResult>> = Vec::new();
            for (prompt, n_tokens) in &requests {
                let (tx, rx) = channel();
                queue
                    .try_push(GenRequest {
                        prompt: prompt.clone(),
                        n_tokens: *n_tokens,
                        sampling,
                        priority: Priority::Interactive,
                        reply: ReplyTo::Channel(tx),
                        affinity: None,
                        enqueued: Instant::now(),
                    })
                    .ok()
                    .ok_or("queue sized for the burst")?;
                rxs.push(rx);
            }
            queue.close();
            let snapshot = Arc::new(Mutex::new(ServeSnapshot::default()));
            let mut sched = Scheduler::new(
                engine,
                queue,
                completions,
                SchedulerConfig {
                    max_sessions,
                    queue_timeout: None,
                    prefill_chunk: chunk,
                    round_budget_tokens: budget,
                    round_batching,
                },
                metrics,
                Arc::clone(&snapshot),
            );
            let mut turns = 0usize;
            while sched.turn().is_some() {
                turns += 1;
                if turns > 100_000 {
                    return Err("scheduler failed to terminate (liveness)".into());
                }
            }
            let mut texts = Vec::new();
            for (i, rx) in rxs.iter().enumerate() {
                let resp = rx
                    .recv()
                    .map_err(|_| format!("request {i} never answered"))?
                    .map_err(|e| format!("request {i} failed: {}", e.message))?;
                if resp.n_generated != requests[i].1 {
                    return Err(format!(
                        "request {i}: n_generated {} != {}",
                        resp.n_generated, requests[i].1
                    ));
                }
                texts.push(resp.text);
            }
            let stats = snapshot.lock().unwrap().round_batching;
            Ok((texts, stats))
        };

        let (legacy_texts, off_stats) = run(false)?;
        let (batched_texts, on_stats) = run(true)?;
        if batched_texts != legacy_texts {
            return Err(format!(
                "{}/{}/prefetch={prefetch}/cap={capacity}/chunk={chunk}/budget={budget}: \
                 round batching changed session outputs",
                policy.name(),
                scheme.name()
            ));
        }
        if off_stats.rounds != 0 || off_stats.batched_rows != 0 {
            return Err(format!(
                "legacy path recorded batched activity: {off_stats:?}"
            ));
        }
        if on_stats.rounds == 0 || on_stats.batched_rows == 0 {
            return Err("batched path recorded no rounds".into());
        }
        if on_stats.batched_rows - on_stats.distinct_experts != on_stats.dedup_joins {
            return Err(format!(
                "dedup ledger broken: rows {} − distinct {} != joins {}",
                on_stats.batched_rows, on_stats.distinct_experts, on_stats.dedup_joins
            ));
        }
        Ok(())
    });
}

#[test]
fn prop_cancel_releases_everything() {
    // mid-decode cancellation invariants (DESIGN.md §9), across random
    // policies × prefetch on/off × chunk sizes × round budgets × cancel
    // points:
    //   * survivors decode bit-identically to a run that never contained
    //     the cancelled sessions — cancellation is isolation, not noise;
    //   * a cancelled session's reply channel drops unanswered, and within
    //     one full turn of the cancel the engine owns no queued prefetch
    //     for it;
    //   * survivors never starve (deficit skip-streak ≤ max_sessions + 1)
    //     even while cancels reshape the round mid-flight;
    //   * the books stay exact: every cancel is counted, nothing lands in
    //     failed_sessions, and the in-flight gauge ends at zero.
    forall(6, |g: &mut Gen| {
        let policy = *g.choose(&PolicyKind::all_online());
        let prefetch = g.bool();
        let chunk = *g.choose(&[0usize, 2, 4]);
        let budget = *g.choose(&[0usize, 2, 6]);
        let max_sessions = g.usize(2..=4);
        let n_keep = g.usize(1..=3);
        let n_doom = g.usize(1..=2);
        let sampling = if g.bool() {
            Sampling::Greedy
        } else {
            Sampling::TopP { temperature: 0.9, top_p: 0.9 }
        };
        let keepers: Vec<(String, usize)> = (0..n_keep)
            .map(|i| (format!("keep {i}"), g.usize(2..=5)))
            .collect();
        // doomed sessions ask for far more tokens than any keeper, so the
        // cancel always lands mid-decode
        let doomed: Vec<(String, usize)> =
            (0..n_doom).map(|i| (format!("doom {i}"), 40)).collect();
        // session ids are assigned in admission (push) order: keepers get
        // 1..=n_keep, doomed n_keep+1..; cancel each doomed session after
        // a random number of generated tokens
        let cancels: std::collections::HashMap<u64, u64> = (0..n_doom)
            .map(|i| ((n_keep + 1 + i) as u64, g.usize(1..=5) as u64))
            .collect();

        let run = |requests: &[(String, usize)],
                   cancels: &std::collections::HashMap<u64, u64>|
         -> Result<(Vec<Option<String>>, u64), String> {
            let cfg_model = ModelConfig { vocab_size: 320, max_seq: 96, ..ModelConfig::TINY };
            let weights = Arc::new(generate_weights(cfg_model, 7));
            let store = Arc::new(HostExpertStore::build(&weights, Scheme::F32).unwrap());
            let engine = InferenceEngine::new(
                Box::new(NativeBackend::new(weights)),
                store,
                EngineConfig::serving(4, policy, prefetch),
            );
            let metrics = Arc::new(ServeMetrics::default());
            let queue = AdmissionQueue::new(requests.len(), Arc::clone(&metrics));
            let (completions, _completion_rx) = channel();
            let mut rxs: Vec<Receiver<GenResult>> = Vec::new();
            for (prompt, n_tokens) in requests {
                let (tx, rx) = channel();
                queue
                    .try_push(GenRequest {
                        prompt: prompt.clone(),
                        n_tokens: *n_tokens,
                        sampling,
                        priority: Priority::Interactive,
                        reply: ReplyTo::Channel(tx),
                        affinity: None,
                        enqueued: Instant::now(),
                    })
                    .ok()
                    .ok_or("queue sized for the burst")?;
                rxs.push(rx);
            }
            queue.close();
            let snapshot = Arc::new(Mutex::new(ServeSnapshot::default()));
            let mut sched = Scheduler::new(
                engine,
                queue,
                completions,
                SchedulerConfig {
                    max_sessions,
                    queue_timeout: None,
                    prefill_chunk: chunk,
                    round_budget_tokens: budget,
                    round_batching: true,
                },
                Arc::clone(&metrics),
                Arc::clone(&snapshot),
            );
            let mut generated: std::collections::HashMap<u64, u64> = Default::default();
            let mut cancelled_at: std::collections::HashMap<u64, usize> = Default::default();
            let mut starving: std::collections::HashMap<u64, usize> = Default::default();
            let mut turns = 0usize;
            while let Some(r) = sched.turn() {
                turns += 1;
                if turns > 100_000 {
                    return Err("scheduler failed to terminate (liveness)".into());
                }
                for a in &r.advanced {
                    if !a.prefill {
                        *generated.entry(a.session).or_insert(0) += a.tokens as u64;
                    }
                    starving.remove(&a.session);
                }
                for &id in &r.skipped {
                    let c = starving.entry(id).or_insert(0);
                    *c += 1;
                    if *c > max_sessions + 1 {
                        return Err(format!(
                            "session {id} skipped {c} consecutive rounds (round {}): starvation",
                            r.round
                        ));
                    }
                }
                for (&id, &after) in cancels {
                    if !cancelled_at.contains_key(&id)
                        && generated.get(&id).copied().unwrap_or(0) >= after
                    {
                        if !sched.cancel(id) {
                            return Err(format!("cancel({id}) found no active session"));
                        }
                        starving.remove(&id);
                        cancelled_at.insert(id, turns);
                    }
                }
                // one full turn after a cancel the engine must hold no
                // queued prefetch for the dead session
                for (&id, &at) in &cancelled_at {
                    if turns > at && sched.engine().pending_prefetch_sessions().contains(&id) {
                        return Err(format!(
                            "cancelled session {id} still owns queued prefetches"
                        ));
                    }
                }
            }
            if cancelled_at.len() != cancels.len() {
                return Err("not every doomed session reached its cancel point".into());
            }
            let mut texts = Vec::new();
            for (i, rx) in rxs.iter().enumerate() {
                match rx.recv() {
                    Ok(Ok(resp)) => {
                        if resp.n_generated != requests[i].1 {
                            return Err(format!(
                                "request {i}: n_generated {} != {}",
                                resp.n_generated, requests[i].1
                            ));
                        }
                        texts.push(Some(resp.text));
                    }
                    Ok(Err(e)) => {
                        return Err(format!("request {i} failed: {}", e.message));
                    }
                    // reply dropped undelivered: the cancelled session
                    Err(_) => texts.push(None),
                }
            }
            if metrics.inflight_sessions.load(Ordering::Relaxed) != 0 {
                return Err(format!(
                    "in-flight gauge leaked or underflowed: {}",
                    metrics.inflight_sessions.load(Ordering::Relaxed)
                ));
            }
            let snap = snapshot.lock().unwrap();
            if snap.failed_sessions != 0 {
                return Err(format!("{} sessions failed", snap.failed_sessions));
            }
            Ok((texts, metrics.cancelled_sessions.load(Ordering::Relaxed)))
        };

        let all: Vec<(String, usize)> =
            keepers.iter().cloned().chain(doomed.iter().cloned()).collect();
        let (ref_texts, ref_cancelled) = run(&keepers, &Default::default())?;
        if ref_cancelled != 0 || ref_texts.iter().any(|t| t.is_none()) {
            return Err("reference run lost sessions without any cancel".into());
        }
        let (texts, cancelled) = run(&all, &cancels)?;
        if cancelled != n_doom as u64 {
            return Err(format!("cancelled_sessions {cancelled} != {n_doom}"));
        }
        for i in 0..n_keep {
            if texts[i] != ref_texts[i] {
                return Err(format!(
                    "{}/prefetch={prefetch}/chunk={chunk}/budget={budget}: survivor {i} \
                     diverged from the cancel-free run",
                    policy.name()
                ));
            }
        }
        for (i, t) in texts.iter().enumerate().skip(n_keep) {
            if t.is_some() {
                return Err(format!("cancelled request {i} was answered anyway"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_precision_recall_identity_for_equal_cardinality() {
    // paper §5.4: |predicted| == |activated| per event => FP == FN
    forall(200, |g: &mut Gen| {
        let mut pr = PrecisionRecall::default();
        for _ in 0..g.usize(1..=50) {
            let k = g.usize(1..=4);
            let mut pred = Vec::new();
            let mut act = Vec::new();
            while pred.len() < k {
                let e = g.usize(0..=9);
                if !pred.contains(&e) {
                    pred.push(e);
                }
            }
            while act.len() < k {
                let e = g.usize(0..=9);
                if !act.contains(&e) {
                    act.push(e);
                }
            }
            pr.record(&pred, &act);
        }
        if pr.fp != pr.fn_ {
            return Err(format!("FP {} != FN {}", pr.fp, pr.fn_));
        }
        Ok(())
    });
}

#[test]
fn prop_quant_roundtrip_within_bound() {
    forall(120, |g: &mut Gen| {
        let data = g.vec_f32(1..=512, -2.0..=2.0);
        if data.is_empty() {
            return Ok(());
        }
        let scheme = *g.choose(&[
            Scheme::Int8 { block: 16 },
            Scheme::Int8 { block: 64 },
            Scheme::Int4 { block: 16 },
            Scheme::Int4 { block: 32 },
        ]);
        let q = QTensor::quantize(&data, scheme);
        let r = q.dequantize();
        let bound = q.max_abs_error_bound() * 1.001;
        for (i, (a, b)) in data.iter().zip(&r).enumerate() {
            if (a - b).abs() > bound {
                return Err(format!(
                    "{:?}[{i}]: {a} vs {b} exceeds bound {bound}",
                    scheme
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_json_roundtrip() {
    fn gen_value(g: &mut Gen, depth: usize) -> Value {
        match if depth == 0 { g.usize(0..=3) } else { g.usize(0..=5) } {
            0 => Value::Null,
            1 => Value::Bool(g.bool()),
            2 => Value::Num((g.f64(-1e6..=1e6) * 100.0).round() / 100.0),
            3 => {
                let n = g.usize(0..=12);
                Value::Str((0..n).map(|_| *g.choose(&['a', 'é', '"', '\\', '\n', '😀', 'z'])).collect())
            }
            4 => Value::Arr((0..g.usize(0..=4)).map(|_| gen_value(g, depth - 1)).collect()),
            _ => Value::Obj(
                (0..g.usize(0..=4))
                    .map(|i| (format!("k{i}"), gen_value(g, depth - 1)))
                    .collect(),
            ),
        }
    }
    forall(300, |g: &mut Gen| {
        let v = gen_value(g, 3);
        let s = json::to_string(&v);
        match json::parse(&s) {
            Ok(v2) if v2 == v => Ok(()),
            Ok(v2) => Err(format!("roundtrip changed value: {v:?} -> {v2:?} via {s}")),
            Err(e) => Err(format!("reparse failed: {e} on {s}")),
        }
    });
}

#[test]
fn prop_topk_is_sorted_prefix() {
    forall(200, |g: &mut Gen| {
        let xs = g.vec_f32(1..=64, -10.0..=10.0);
        if xs.is_empty() {
            return Ok(());
        }
        let k = g.usize(1..=xs.len().min(8));
        let idx = top_k(&xs, k);
        if idx.len() != k {
            return Err("wrong k".into());
        }
        // every selected >= every non-selected
        let min_sel = idx.iter().map(|&i| xs[i]).fold(f32::INFINITY, f32::min);
        for (i, &x) in xs.iter().enumerate() {
            if !idx.contains(&i) && x > min_sel {
                return Err(format!("unselected xs[{i}]={x} > min selected {min_sel}"));
            }
        }
        // descending order
        for w in idx.windows(2) {
            if xs[w[0]] < xs[w[1]] {
                return Err("not descending".into());
            }
        }
        Ok(())
    });
}

#[test]
fn prop_replay_hit_miss_conservation() {
    // hits + misses == total activations, for every policy
    forall(40, |g: &mut Gen| {
        let seed = g.usize(0..=9999) as u64;
        let trace = tracegen::generate(&tracegen::TraceGenConfig {
            n_layers: 3,
            n_tokens: 50,
            seed,
            ..Default::default()
        });
        let total = (50 * 3 * 2) as u64;
        for kind in [PolicyKind::Lru, PolicyKind::Lfu, PolicyKind::Belady] {
            let r = cachesim::compare(&trace, &[kind], g.usize(1..=8), seed);
            let s = &r[0].stats;
            if s.hits + s.misses != total {
                return Err(format!("{:?}: {} + {} != {total}", kind, s.hits, s.misses));
            }
        }
        Ok(())
    });
}
