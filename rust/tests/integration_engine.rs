//! Integration tests: the full engine stack (cache + offload + backend)
//! over the native oracle, artifact-free.
//!
//! The headline invariant is **semantic transparency** (DESIGN.md §3 /
//! Table-1 quality substitution): the expert cache stores *weights*, so no
//! choice of policy, capacity, speculation, or overlap may change a single
//! generated token when quantization is held fixed.

use moe_offload::cache::PolicyKind;
use moe_offload::engine::{EngineConfig, GenerationOutput, InferenceEngine};
use moe_offload::model::sampler::{Sampler, Sampling};
use moe_offload::model::weights::generate_weights;
use moe_offload::model::ModelConfig;
use moe_offload::offload::prefetch::PrefetchConfig;
use moe_offload::offload::store::{HostExpertStore, HostTierConfig};
use moe_offload::quant::Scheme;
use moe_offload::runtime::native::NativeBackend;
use moe_offload::sim::hardware;
use std::sync::Arc;

const CFG: ModelConfig = ModelConfig::TINY;

fn run(
    policy: PolicyKind,
    capacity: usize,
    scheme: Scheme,
    spec: bool,
    transfer_workers: usize,
    seed: u64,
) -> GenerationOutput {
    let weights = Arc::new(generate_weights(CFG, 42));
    let store = Arc::new(HostExpertStore::build(&weights, scheme).unwrap());
    run_with_store(store, policy, capacity, spec, transfer_workers, seed)
}

fn run_with_store(
    store: Arc<HostExpertStore>,
    policy: PolicyKind,
    capacity: usize,
    spec: bool,
    transfer_workers: usize,
    seed: u64,
) -> GenerationOutput {
    let weights = Arc::new(generate_weights(CFG, 42));
    let mut engine = InferenceEngine::new(
        Box::new(NativeBackend::new(weights)),
        store,
        EngineConfig {
            cache_capacity: capacity,
            policy,
            prefetch: PrefetchConfig { enabled: spec, k: 2 },
            transfer_workers,
            profile: hardware::by_name("A6000").unwrap(),
            disk: hardware::DiskProfile::default(),
            seed,
            record_trace: true,
            fetch_retries: 2,
            demand_deadline_ms: 0,
            ..EngineConfig::default()
        },
    );
    let mut sampler = Sampler::new(Sampling::Greedy, seed);
    engine.generate(&[1, 5, 9], 8, &mut sampler).unwrap()
}

/// Tiered store bounded to `budget_entries` RAM slots (rest spilled to disk).
fn tiered_store(scheme: Scheme, budget_entries: usize) -> Arc<HostExpertStore> {
    let weights = Arc::new(generate_weights(CFG, 42));
    let entry_bytes = HostExpertStore::build(&weights, scheme)
        .unwrap()
        .expert_transfer_bytes();
    let tier = HostTierConfig::new(budget_entries * entry_bytes);
    Arc::new(HostExpertStore::build_tiered(&weights, scheme, &tier).unwrap())
}

#[test]
fn semantic_transparency_across_policies() {
    let baseline = run(PolicyKind::Lru, 8, Scheme::F32, false, 0, 0);
    for policy in [PolicyKind::Lfu, PolicyKind::LfuAged, PolicyKind::Fifo, PolicyKind::Random] {
        for capacity in [1, 2, 4, 8] {
            let out = run(policy, capacity, Scheme::F32, false, 0, 0);
            assert_eq!(
                out.tokens, baseline.tokens,
                "{:?} cap={capacity} changed generated tokens",
                policy
            );
        }
    }
}

#[test]
fn semantic_transparency_with_speculation_and_overlap() {
    let baseline = run(PolicyKind::Lru, 4, Scheme::F32, false, 0, 0);
    let spec = run(PolicyKind::Lru, 4, Scheme::F32, true, 0, 0);
    let spec_overlap = run(PolicyKind::Lru, 4, Scheme::F32, true, 2, 0);
    assert_eq!(baseline.tokens, spec.tokens, "speculation changed outputs");
    assert_eq!(baseline.tokens, spec_overlap.tokens, "overlap changed outputs");
}

#[test]
fn generation_deterministic_per_seed() {
    let a = run(PolicyKind::Lfu, 4, Scheme::Int8 { block: 16 }, true, 0, 7);
    let b = run(PolicyKind::Lfu, 4, Scheme::Int8 { block: 16 }, true, 0, 7);
    assert_eq!(a.tokens, b.tokens);
    assert_eq!(a.cache_stats.hits, b.cache_stats.hits);
    assert_eq!(a.transfer_bytes, b.transfer_bytes);
}

#[test]
fn smaller_cache_transfers_more() {
    let big = run(PolicyKind::Lru, 8, Scheme::Int4 { block: 16 }, false, 0, 0);
    let small = run(PolicyKind::Lru, 2, Scheme::Int4 { block: 16 }, false, 0, 0);
    assert!(small.transfer_bytes > big.transfer_bytes);
    assert!(small.cache_stats.hit_rate() < big.cache_stats.hit_rate() + 1e-9);
    // peak resident memory shrinks with the cache
    assert!(small.peak_resident_bytes < big.peak_resident_bytes);
}

#[test]
fn full_cache_hits_after_first_touch() {
    let out = run(PolicyKind::Lru, CFG.n_experts, Scheme::F32, false, 0, 0);
    // every expert missed at most once per layer
    assert!(out.cache_stats.misses <= (CFG.n_layers * CFG.n_experts) as u64);
    assert_eq!(out.cache_stats.evictions, 0);
}

#[test]
fn speculative_precision_equals_recall() {
    let out = run(PolicyKind::Lru, 4, Scheme::F32, true, 0, 0);
    let pr = out.spec_pr;
    assert!(pr.tp + pr.fp > 0, "no speculation happened");
    assert_eq!(pr.fp, pr.fn_, "paper §5.4 identity violated");
    assert!((pr.precision() - pr.recall()).abs() < 1e-12);
}

#[test]
fn trace_records_every_token_layer() {
    let out = run(PolicyKind::Lfu, 4, Scheme::F32, true, 0, 0);
    let t = out.trace.expect("trace");
    assert_eq!(t.n_tokens(), 11); // 3 prompt + 8 generated
    for tok in 0..t.n_tokens() {
        for l in 0..CFG.n_layers {
            let rec = t.at(tok, l);
            assert_eq!(rec.activated.len(), CFG.top_k);
            assert_eq!(rec.weights.len(), CFG.top_k);
            let wsum: f32 = rec.weights.iter().sum();
            assert!((wsum - 1.0).abs() < 1e-4, "weights not renormalized: {wsum}");
            assert!(rec.cached_before.len() <= 4);
            if l > 0 {
                assert!(rec.spec_guess.is_some(), "missing spec guess at layer {l}");
            } else {
                assert!(rec.spec_guess.is_none(), "layer 0 cannot be guessed");
            }
        }
    }
}

#[test]
fn sim_clock_slower_on_worse_bandwidth() {
    let weights = Arc::new(generate_weights(CFG, 42));
    let mut outs = Vec::new();
    for profile in ["A100", "RTX3090"] {
        let store =
            Arc::new(HostExpertStore::build(&weights, Scheme::Int4 { block: 16 }).unwrap());
        let mut engine = InferenceEngine::new(
            Box::new(NativeBackend::new(Arc::clone(&weights))),
            store,
            EngineConfig {
                cache_capacity: 2,
                policy: PolicyKind::Lru,
                prefetch: PrefetchConfig::default(),
                transfer_workers: 0,
                profile: hardware::by_name(profile).unwrap(),
                disk: hardware::DiskProfile::default(),
                seed: 0,
                record_trace: false,
                fetch_retries: 2,
                demand_deadline_ms: 0,
                ..EngineConfig::default()
            },
        );
        let mut sampler = Sampler::new(Sampling::Greedy, 0);
        outs.push(engine.generate(&[1, 2], 6, &mut sampler).unwrap());
    }
    // same trace, same misses; 3090's lower bandwidth + compute => slower sim
    assert_eq!(outs[0].tokens, outs[1].tokens);
    assert!(outs[0].throughput.sim_s < outs[1].throughput.sim_s);
}

#[test]
fn quantized_decode_stays_coherent() {
    // int8/int4 perturb logits but the engine must still run to completion
    // with valid expert selections and normalized weights.
    for scheme in [Scheme::Int8 { block: 16 }, Scheme::Int4 { block: 16 }] {
        let out = run(PolicyKind::Lfu, 4, scheme, false, 0, 0);
        assert_eq!(out.generated.len(), 8);
        let t = out.trace.unwrap();
        for tok in 0..t.n_tokens() {
            for l in 0..CFG.n_layers {
                assert_eq!(t.at(tok, l).activated.len(), CFG.top_k);
            }
        }
    }
}

#[test]
fn tiered_store_is_bit_identical_to_all_ram() {
    // A RAM budget below the full expert set (TINY = 16 entries) forces disk
    // spills + promotions, yet generation must not change by a single token:
    // the disk tier only moves bytes, it never rewrites them.
    for scheme in [Scheme::F32, Scheme::Int8 { block: 16 }, Scheme::Int4 { block: 16 }] {
        let baseline = run(PolicyKind::Lru, 4, scheme, false, 0, 0);
        for budget_entries in [1, 3] {
            let out = run_with_store(
                tiered_store(scheme, budget_entries),
                PolicyKind::Lru,
                4,
                false,
                0,
                0,
            );
            assert_eq!(
                out.tokens, baseline.tokens,
                "{scheme:?} budget={budget_entries} changed generated tokens"
            );
            assert_eq!(out.cache_stats.hits, baseline.cache_stats.hits);
            assert_eq!(out.transfer_bytes, baseline.transfer_bytes);
            // disk reads only ever slow the simulated clock down
            assert!(out.throughput.sim_s >= baseline.throughput.sim_s);
        }
    }
}

#[test]
fn tiered_counters_obey_access_invariant_through_engine() {
    let store = tiered_store(Scheme::Int8 { block: 16 }, 2);
    let out = run_with_store(Arc::clone(&store), PolicyKind::Lfu, 4, true, 2, 0);
    assert_eq!(out.generated.len(), 8);
    let ht = store.tier_stats();
    assert!(ht.host_accesses > 0, "engine never touched the host tier");
    assert_eq!(
        ht.ram_hits + ht.disk_promotions,
        ht.host_accesses,
        "every host access must be a RAM hit or a disk promotion"
    );
    assert!(ht.disk_promotions > 0, "budget of 2 entries must spill");
    assert!(ht.ram_evictions > 0, "16 experts through 2 slots must evict");
}

#[test]
fn rejects_overlong_sequence() {
    let weights = Arc::new(generate_weights(CFG, 42));
    let store = Arc::new(HostExpertStore::build(&weights, Scheme::F32).unwrap());
    let mut engine = InferenceEngine::new(
        Box::new(NativeBackend::new(weights)),
        store,
        EngineConfig::baseline_lru(4),
    );
    let mut sampler = Sampler::new(Sampling::Greedy, 0);
    let long_prompt = vec![1u32; CFG.max_seq];
    assert!(engine.generate(&long_prompt, 5, &mut sampler).is_err());
    assert!(engine.generate(&[], 5, &mut sampler).is_err());
}
