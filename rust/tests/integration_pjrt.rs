//! PJRT integration tests: the AOT path against the shipped artifacts.
//! These are skipped (with a notice) when `artifacts/` has not been built,
//! so `cargo test` works before `make artifacts`; CI runs `make test`
//! which builds artifacts first.

use moe_offload::cache::PolicyKind;
use moe_offload::engine::{selfcheck, EngineConfig, InferenceEngine};
use moe_offload::model::sampler::{Sampler, Sampling};
use moe_offload::model::Weights;
use moe_offload::offload::prefetch::PrefetchConfig;
use moe_offload::offload::store::HostExpertStore;
use moe_offload::quant::Scheme;
use moe_offload::runtime::{artifacts::Artifacts, native::NativeBackend, pjrt::PjrtBackend, Backend};
use moe_offload::sim::hardware;
use std::path::Path;
use std::sync::Arc;

fn load() -> Option<(Artifacts, Arc<Weights>)> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    match Artifacts::load(&dir) {
        Ok(a) => {
            let w = Arc::new(Weights::load(&a.weights_path).unwrap());
            Some((a, w))
        }
        Err(_) => {
            eprintln!("NOTE: artifacts/ not built; skipping PJRT integration test");
            None
        }
    }
}

#[test]
fn pjrt_matches_native_stagewise() {
    let Some((artifacts, weights)) = load() else { return };
    let pjrt = PjrtBackend::new(&artifacts, &weights).unwrap();
    let native = NativeBackend::new(Arc::clone(&weights));
    let h = weights.config.hidden_size;
    let x: Vec<f32> = (0..h).map(|i| (i as f32 * 0.37).sin() * 0.5).collect();

    // embed
    let (a, b) = (pjrt.embed(11).unwrap(), native.embed(11).unwrap());
    assert_close(&a, &b, 1e-6, "embed");

    // attn chain over 3 positions keeps caches coherent across backends
    let mut kva = pjrt.new_kv().unwrap();
    let mut kvb = native.new_kv().unwrap();
    let mut xa = x.clone();
    let mut xb = x.clone();
    for pos in 0..3 {
        xa = pjrt.attn(0, &xa, &mut kva, pos).unwrap();
        xb = native.attn(0, &xb, &mut kvb, pos).unwrap();
        assert_close(&xa, &xb, 5e-4, "attn chain");
    }

    // router + spec router
    let (ha, pa) = pjrt.router(1, &x).unwrap();
    let (hb, pb) = native.router(1, &x).unwrap();
    assert_close(&ha, &hb, 5e-5, "router.h");
    assert_close(&pa, &pb, 1e-5, "router.probs");
    let sa = pjrt.spec_router(2, &x).unwrap();
    let sb = native.spec_router(2, &x).unwrap();
    assert_close(&sa, &sb, 1e-5, "spec_router");

    // expert via upload path
    let w1 = weights.expert(0, 0, "w1").unwrap().to_vec();
    let w3 = weights.expert(0, 0, "w3").unwrap().to_vec();
    let w2 = weights.expert(0, 0, "w2").unwrap().to_vec();
    let ea = pjrt
        .expert(&ha, &pjrt.upload_expert(w1.clone(), w3.clone(), w2.clone()).unwrap())
        .unwrap();
    let eb = native.expert(&hb, &native.upload_expert(w1, w3, w2).unwrap()).unwrap();
    assert_close(&ea, &eb, 2e-3, "expert");

    // final logits
    let (fa, fb) = (pjrt.final_logits(&x).unwrap(), native.final_logits(&x).unwrap());
    assert_close(&fa, &fb, 1e-3, "final");
}

#[test]
fn pjrt_engine_decode_with_quantized_store() {
    let Some((artifacts, weights)) = load() else { return };
    let backend: Box<dyn Backend> = Box::new(PjrtBackend::new(&artifacts, &weights).unwrap());
    let store = Arc::new(HostExpertStore::build(&weights, Scheme::Int4 { block: 16 }).unwrap());
    let mut engine = InferenceEngine::new(
        backend,
        store,
        EngineConfig {
            cache_capacity: 4,
            policy: PolicyKind::Lfu,
            prefetch: PrefetchConfig { enabled: true, k: 2 },
            transfer_workers: 0,
            profile: hardware::by_name("A100").unwrap(),
            disk: hardware::DiskProfile::default(),
            seed: 0,
            record_trace: true,
            fetch_retries: 2,
            demand_deadline_ms: 0,
            ..EngineConfig::default()
        },
    );
    let mut sampler = Sampler::new(Sampling::Greedy, 0);
    let out = engine.generate(&[1, 7, 42], 4, &mut sampler).unwrap();
    assert_eq!(out.generated.len(), 4);
    assert!(out.cache_stats.hits > 0);
    let pr = out.spec_pr;
    assert_eq!(pr.fp, pr.fn_, "speculation identity");
}

#[test]
fn selfcheck_passes_for_both_backends() {
    let Some((artifacts, weights)) = load() else { return };
    for kind in ["native", "pjrt"] {
        let rep = selfcheck::run_all(
            || {
                Ok(match kind {
                    "pjrt" => Box::new(PjrtBackend::new(&artifacts, &weights)?) as Box<dyn Backend>,
                    _ => Box::new(NativeBackend::new(Arc::clone(&weights))),
                })
            },
            &artifacts,
            Arc::clone(&weights),
        )
        .unwrap();
        assert!(rep.passed, "{kind} selfcheck:\n{}", rep.render());
    }
}

fn assert_close(a: &[f32], b: &[f32], tol: f32, what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    let max = a
        .iter()
        .zip(b)
        .map(|(x, y)| (x - y).abs())
        .fold(0.0f32, f32::max);
    assert!(max <= tol, "{what}: max_abs_err {max} > {tol}");
}
